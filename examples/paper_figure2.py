"""Reproduce paper Figure 2: DSGD / DSGT / MC-DSGT on non-convex-regularized
logistic regression over random time-varying sun-shaped graphs.

Left plot protocol:  (n, |C|) = (16, 1), R = 2, MNIST-like  (d = 784)
Right plot protocol: (n, |C|) = (32, 4), R = 4, COVTYPE-like (d = 54)

Heterogeneous partition: half the nodes hold 80% positive labels, the other
half 80% negative (§6).  Datasets are synthetic stand-ins with the same
shapes (no network access in this container); the *algorithmic* comparison
— the figure's actual claim — is preserved.  Each (protocol, algorithm,
stepsize) cell is one :class:`repro.exp.ExperimentSpec` (the §6 randomized
sun schedule is the registered ``random-sun`` topology) run through
``repro.exp.run``.  Writes CSV curves to experiments/figure2_<name>.csv.

    PYTHONPATH=src python examples/paper_figure2.py [--steps 400]
"""

import argparse
import os

from repro import exp
from repro.configs.logreg_paper import COVTYPE, MNIST
from repro.obs import Console


def base_spec(lc, seed: int = 0) -> exp.ExperimentSpec:
    """The protocol's scenario literal — everything but the algorithm cell."""
    return exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=lc.d, m=lc.m, rho=lc.rho),
        data=exp.DataSpec(batch=lc.batch),
        topology=exp.TopologySpec(kind="random-sun", centers=lc.center_size),
        run=exp.RunSpec(nodes=lc.n_nodes, seed=seed))


# the CI spec-smoke pool (repro.exp.validate runs each for 2 steps)
SPECS = {
    "mnist_mc_dsgt": exp.with_overrides(base_spec(MNIST), {
        "algorithm.name": "mc_dsgt", "algorithm.R": MNIST.R,
        "algorithm.gamma": 0.5, "run.steps": 4}),
}


def run_setup(lc, T_budget: int, gamma: float, seed: int = 0,
              con: Console = None):
    con = con or Console.from_argv()
    base = base_spec(lc, seed)

    # per-algorithm step-size tuning over a small grid (the paper reports
    # tuned curves): MC-DSGT's R-fold gradient accumulation cuts oracle
    # noise by R, admitting up to ~R x larger steps at equal stability.
    def tuned(algo, R, steps, gammas):
        best = None
        for g in gammas:
            spec = exp.with_overrides(base, {
                "algorithm.name": algo, "algorithm.gamma": g,
                "algorithm.R": R, "run.steps": steps,
                "run.eval_every": max(1, steps // 40)})
            res = exp.run(spec, quiet=con.quiet)
            pts = [(t, float(v)) for t, v in res.history]
            if best is None or pts[-1][1] < best[-1][1]:
                best = pts
        return best

    curves = {}
    grid = [gamma, 2 * gamma]
    mc_grid = sorted({gamma, gamma * lc.R / 2, gamma * lc.R})
    curves["dsgd"] = tuned("dsgd", 1, T_budget, grid)
    curves["dsgt"] = tuned("dsgt", 1, T_budget // 2, grid)
    curves[f"mc_dsgt(R={lc.R})"] = tuned(
        "mc_dsgt", lc.R, T_budget // (2 * lc.R), mc_grid)
    for name, pts in curves.items():
        con.event("curve", setup=lc.name, algo=name, grad_sq=pts[-1][1])
    return curves


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400,
                    help="total per-node round budget T")
    ap.add_argument("--out", default="experiments")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    con = Console(quiet=args.quiet)

    os.makedirs(args.out, exist_ok=True)
    all_results = {}
    for lc, gamma in [(MNIST, 0.5), (COVTYPE, 0.5)]:
        con.print(f"setup {lc.name}: n={lc.n_nodes} |C|={lc.center_size} "
                  f"R={lc.R} rho={lc.rho}")
        curves = run_setup(lc, args.steps, gamma, con=con)
        all_results[lc.name] = curves
        path = os.path.join(args.out, f"figure2_{lc.name}.csv")
        with open(path, "w") as f:
            f.write("algo,T,grad_norm_sq\n")
            for name, pts in curves.items():
                for t, g in pts:
                    f.write(f"{name},{t},{g}\n")
        con.event("wrote", path=path)

    # the figure's claim: MC-DSGT converges lower at equal budget (or to
    # parity when the random schedule mixes fast and both sit at the
    # gradient-noise floor, as for the |C|=4 covtype protocol)
    for name, curves in all_results.items():
        final = {k: v[-1][1] for k, v in curves.items()}
        mc = min(v for k, v in final.items() if k.startswith("mc"))
        if mc <= final["dsgd"]:
            verdict = "beats"
        elif mc < 1e-4 and final["dsgd"] < 1e-4:
            verdict = "matches (both at the noise floor)"
        else:
            verdict = "LOSES to"
        con.print(f"{name}: MC-DSGT {verdict} DSGD "
                  f"({mc:.6f} vs {final['dsgd']:.6f})")
    return all_results


if __name__ == "__main__":
    main()
