"""Reproduce paper Figure 2: DSGD / DSGT / MC-DSGT on non-convex-regularized
logistic regression over random time-varying sun-shaped graphs.

Left plot protocol:  (n, |C|) = (16, 1), R = 2, MNIST-like  (d = 784)
Right plot protocol: (n, |C|) = (32, 4), R = 4, COVTYPE-like (d = 54)

Heterogeneous partition: half the nodes hold 80% positive labels, the other
half 80% negative (§6).  Datasets are synthetic stand-ins with the same
shapes (no network access in this container); the *algorithmic* comparison
— the figure's actual claim — is preserved.  Writes CSV curves to
experiments/figure2_<name>.csv.

    PYTHONPATH=src python examples/paper_figure2.py [--steps 400]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.logreg_paper import COVTYPE, MNIST
from repro.core import algorithms as alg
from repro.core import driver, gossip, topology as topo
from repro.data import logreg_dataset, logreg_loss_and_grad


def random_sun_schedule(n: int, c_size: int, period: int = 16, seed: int = 0):
    """Random time-varying sun-shaped graphs with |C| = c_size (the §6
    protocol: centers re-drawn randomly each round)."""
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(period):
        center = rng.choice(n, size=c_size, replace=False)
        adj = topo.sun_shaped_graph(n, center)
        mats.append(gossip.laplacian_rule(adj))
    return gossip.WeightSchedule(tuple(mats))


def run_setup(lc, T_budget: int, gamma: float, seed: int = 0):
    n = lc.n_nodes
    H, y = logreg_dataset(n, lc.m, lc.d, seed=seed)
    _, _, stoch_grad, global_loss, gnorm2 = logreg_loss_and_grad(lc.rho)
    sched = random_sun_schedule(n, lc.center_size, seed=seed)
    x0 = jnp.zeros((n, lc.d))

    def grad_fn(xs, key):
        return stoch_grad(xs, H, y, key, lc.batch)

    def eval_fn(xbar):
        return gnorm2(xbar, H, y)

    # per-algorithm step-size tuning over a small grid (the paper reports
    # tuned curves): MC-DSGT's R-fold gradient accumulation cuts oracle
    # noise by R, admitting up to ~R x larger steps at equal stability.
    def tuned(make_algo, steps, gammas):
        # each candidate runs through the unified driver (staged schedule,
        # in-jit window gather) — no hand-rolled loop
        best = None
        for g in gammas:
            _, hist = driver.run_algorithm(make_algo(g), x0, grad_fn, sched,
                                           steps, jax.random.key(seed),
                                           eval_fn=eval_fn,
                                           eval_every=max(1, steps // 40))
            pts = [(t, float(v)) for t, v in hist]
            if best is None or pts[-1][1] < best[-1][1]:
                best = pts
        return best

    curves = {}
    grid = [gamma, 2 * gamma]
    mc_grid = sorted({gamma, gamma * lc.R / 2, gamma * lc.R})
    curves["dsgd"] = tuned(lambda g: alg.dsgd(g), T_budget, grid)
    curves["dsgt"] = tuned(lambda g: alg.dsgt(g), T_budget // 2, grid)
    curves[f"mc_dsgt(R={lc.R})"] = tuned(
        lambda g: alg.mc_dsgt(g, R=lc.R), T_budget // (2 * lc.R), mc_grid)
    for name, pts in curves.items():
        print(f"  {lc.name} {name:14s} final ||grad||^2 = {pts[-1][1]:.6f}")
    return curves


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400,
                    help="total per-node round budget T")
    ap.add_argument("--out", default="experiments")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    all_results = {}
    for lc, gamma in [(MNIST, 0.5), (COVTYPE, 0.5)]:
        print(f"setup {lc.name}: n={lc.n_nodes} |C|={lc.center_size} "
              f"R={lc.R} rho={lc.rho}")
        curves = run_setup(lc, args.steps, gamma)
        all_results[lc.name] = curves
        path = os.path.join(args.out, f"figure2_{lc.name}.csv")
        with open(path, "w") as f:
            f.write("algo,T,grad_norm_sq\n")
            for name, pts in curves.items():
                for t, g in pts:
                    f.write(f"{name},{t},{g}\n")
        print(f"  wrote {path}")

    # the figure's claim: MC-DSGT converges lower at equal budget (or to
    # parity when the random schedule mixes fast and both sit at the
    # gradient-noise floor, as for the |C|=4 covtype protocol)
    for name, curves in all_results.items():
        final = {k: v[-1][1] for k, v in curves.items()}
        mc = min(v for k, v in final.items() if k.startswith("mc"))
        if mc <= final["dsgd"]:
            verdict = "beats"
        elif mc < 1e-4 and final["dsgd"] < 1e-4:
            verdict = "matches (both at the noise floor)"
        else:
            verdict = "LOSES to"
        print(f"{name}: MC-DSGT {verdict} DSGD "
              f"({mc:.6f} vs {final['dsgd']:.6f})")
    return all_results


if __name__ == "__main__":
    main()
