"""End-to-end driver: decentralized training of a transformer LM with
MC-DSGT over a time-varying sun-shaped network.

Default: ~10M-param qwen-family model, 8 nodes, a few hundred steps (sized
for the CPU container; pass --preset full --steps 300 on real hardware for
the ~0.5B config).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.launch.train import main as train_main
from repro.obs import Console


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="reduced")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--checkpoint", default="experiments/lm_ckpt.msgpack")
    ap.add_argument("--metrics", default=None,
                    help="repro.obs JSONL event-log path")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    con = Console(quiet=args.quiet)

    flags = [
        "--arch", args.arch, "--preset", args.preset,
        "--steps", str(args.steps), "--nodes", str(args.nodes),
        "--beta", "0.875", "--topology", "sun", "--algo", "mc_dsgt",
        "--R", "2", "--gamma", "0.1", "--batch", "4", "--seq", "64",
        "--checkpoint", args.checkpoint, "--log-every", "10",
    ]
    if args.metrics:
        flags += ["--metrics", args.metrics]
    if args.quiet:
        flags += ["--quiet"]
    history = train_main(flags)
    first, last = history[0]["loss"], history[-1]["loss"]
    con.event("trained", loss_first=first, loss_last=last,
              steps=args.steps,
              improved=str(last < first).lower())
    return history


if __name__ == "__main__":
    main()
