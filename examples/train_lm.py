"""End-to-end driver: decentralized training of a transformer LM with
MC-DSGT over a time-varying sun-shaped network.

Default: ~10M-param qwen-family model, 8 nodes, a few hundred steps (sized
for the CPU container; pass --preset full --steps 300 on real hardware for
the ~0.5B config).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="reduced")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--checkpoint", default="experiments/lm_ckpt.msgpack")
    args = ap.parse_args(argv)

    history = train_main([
        "--arch", args.arch, "--preset", args.preset,
        "--steps", str(args.steps), "--nodes", str(args.nodes),
        "--beta", "0.875", "--topology", "sun", "--algo", "mc_dsgt",
        "--R", "2", "--gamma", "0.1", "--batch", "4", "--seq", "64",
        "--checkpoint", args.checkpoint, "--log-every", "10",
    ])
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    return history


if __name__ == "__main__":
    main()
