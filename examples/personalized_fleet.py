"""Personalized fleet, trained to served: the train->serve loop end to end.

16 nodes with strongly non-iid data (Dirichlet(0.1) token marginals) move
through the unit square (random-waypoint mobility, unit-disk links) while
the channel drops 20% of links per round — the paper's wireless scenario.
Two fleets train on the SAME realized scenario and gossip budget:

* ``personalized`` — loss-proximity neighbor averaging (similarity-gated
  row-stochastic mixing, outside Assumption 3): nodes with similar losses
  share aggressively, dissimilar nodes mostly keep their own model, so the
  fleet converges to genuinely distinct per-node models;
* ``mc_dsgt``      — the paper's uniform consensus baseline: every node is
  driven toward ONE shared model, which under non-iid data is a compromise
  no node's own distribution prefers.

The example evaluates both fleets per node on held-out batches from each
node's OWN stream (the metric a personalized deployment cares about), then
serves the personalized fleet behind one continuously batched endpoint:
64 synthetic requests, each user pinned to one node's personalization
(``user-affinity`` routing), decoded slot-wise against that node's
parameters (:mod:`repro.serve`).

    PYTHONPATH=src python examples/personalized_fleet.py
"""

import jax
import numpy as np

from repro import exp
from repro.obs import Console

N = 16
T = 60                     # gossip/oracle budget per training run
ALPHA = 0.1                # Dirichlet token-marginal heterogeneity

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="arch", arch="qwen1.5-0.5b", preset="reduced"),
    data=exp.DataSpec(batch=8, seq=32, active_vocab=64, hetero_alpha=ALPHA),
    topology=exp.TopologySpec(kind="waypoint-mobility", radius=0.45),
    channel=exp.ChannelSpec(link_drop=0.2),
    run=exp.RunSpec(nodes=N, log_every=10),
)

_ALGOS = {          # name -> extra algorithm fields
    "personalized": {"algorithm.gamma": 0.3, "algorithm.tau": 8.0},
    "mc_dsgt": {"algorithm.gamma": 0.3, "algorithm.R": 2},
}


def _spec(algo: str, requests: int = 0) -> exp.ExperimentSpec:
    spec = exp.with_overrides(_BASE, {"algorithm.name": algo,
                                      **_ALGOS[algo]})
    # equal budget T: rounds per step come from the engine rule itself
    steps = max(2, T // exp.weights_per_step(spec.algorithm))
    return exp.with_overrides(spec, {
        "run.steps": steps,
        "serve.requests": requests, "serve.batch": 8,
        "serve.prompt_len": 16, "serve.max_new": 16,
        "serve.routing": "user-affinity"})


# the CI spec-smoke pool: the serve-phase cell (exp.validate --only serve)
SPECS = {"personalized_serve": _spec("personalized", requests=64)}


def per_node_eval_loss(res: exp.Result, batches: int = 4) -> np.ndarray:
    """(n,) mean held-out loss of each node's model on ITS OWN stream:
    batches drawn past the training horizon (same Dirichlet marginals,
    never trained on)."""
    built = res.built
    loss1 = jax.jit(jax.vmap(
        lambda p, t: built.model.train_loss(p, {"tokens": t})))
    total = 0.0
    for j in range(batches):
        toks = built.stream.batch_at(res.spec.run.steps + 2 + j)["tokens"]
        total += loss1(res.state.x, toks[:, 0])
    return np.asarray(total / batches)


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N}  waypoint mobility + 20% link drop  "
              f"Dirichlet({ALPHA}) non-iid token streams  budget T={T}")

    # -- uniform consensus baseline ----------------------------------------
    base = exp.run(_spec("mc_dsgt"), quiet=True)
    base_pn = per_node_eval_loss(base)
    con.event("result", algo="mc_dsgt", per_node_loss=float(base_pn.mean()),
              worst_node=float(base_pn.max()))

    # -- personalized fleet, trained then served ---------------------------
    res = exp.run(_spec("personalized", requests=64), quiet=con.quiet)
    pers_pn = per_node_eval_loss(res)
    con.event("result", algo="personalized",
              per_node_loss=float(pers_pn.mean()),
              worst_node=float(pers_pn.max()))

    sv = res.serve
    tp = sv.throughput
    nodes_hit = sorted({c["node"] for c in sv.completed})
    users = {}
    for c in sv.completed:
        users.setdefault(c["user"], set()).add(c["node"])
    con.event("served", requests=tp["requests"], fleet=sv.fleet,
              batch=tp["batch"], decode_tok_s=tp["decode_tok_s"],
              p50_ms=tp["latency_p50_ms"], p95_ms=tp["latency_p95_ms"],
              nodes_hit=len(nodes_hit))

    con.print("\nPersonalization pays exactly where consensus cannot: under "
              "Dirichlet non-iid streams each node's own-data loss is lower "
              "for the loss-proximity fleet than for the single consensus "
              "model, and the serve phase routes every user to the one node "
              "whose personalization they pinned.")
    assert float(pers_pn.mean()) < float(base_pn.mean()), \
        (f"personalized per-node loss {pers_pn.mean():.4f} should beat "
         f"uniform mc_dsgt {base_pn.mean():.4f} on non-iid data")
    assert tp["requests"] == 64, f"served {tp['requests']}/64 requests"
    assert all(len(v) == 1 for v in users.values()), \
        "user-affinity routing must pin each user to exactly one node"
    return {"personalized": float(pers_pn.mean()),
            "mc_dsgt": float(base_pn.mean()), "throughput": tp}


if __name__ == "__main__":
    main()
