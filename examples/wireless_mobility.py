"""Wireless mobility + lossy channels: the paper's motivating scenario.

"Decentralized algorithms are more robust in wireless scenarios especially
when nodes are moving" — this example is that scenario as a spec grid:
16 nodes move through the unit square (random-waypoint mobility, unit-disk
links), the channel drops an increasing fraction of links per round (iid
Bernoulli), the surviving links are repaired into a valid mixing matrix,
and MC-DSGT / DSGD / gt_local run over the *realized* schedule.  The whole
{algorithm} x {drop rate} matrix is ``repro.exp.sweep`` over ONE base
:class:`~repro.exp.ExperimentSpec`; the mobility, channel, repair, and
telemetry wiring all come from ``run(spec)``.

    PYTHONPATH=src python examples/wireless_mobility.py
"""

import numpy as np

from repro import exp
from repro.obs import Console

N = 16
T = 320                    # gossip/oracle budget per run
R = 2                      # MC-DSGT consensus/accumulation rounds
DROPS = (0.0, 0.2, 0.4)

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="logreg", d=64, m=256, rho=0.1),
    data=exp.DataSpec(batch=16, hetero_alpha=0.3),
    topology=exp.TopologySpec(kind="waypoint-mobility", radius=0.45),
    run=exp.RunSpec(nodes=N),
)

_ALGOS = {          # name -> (gamma, R)
    "mc_dsgt": (0.3, R),
    "gt_local": (0.2, 1),
    "dsgd": (0.3, 1),
}


def _spec(algo: str, drop: float) -> exp.ExperimentSpec:
    gamma, rr = _ALGOS[algo]
    spec = exp.with_overrides(_BASE, {
        "algorithm.name": algo, "algorithm.gamma": gamma, "algorithm.R": rr,
        "channel.link_drop": drop})
    # equal budget T: rounds per step come from the engine rule itself
    steps = max(2, T // exp.weights_per_step(spec.algorithm))
    return exp.with_overrides(spec, {
        "run.steps": steps, "run.eval_every": max(1, steps - 1)})


# the CI spec-smoke pool (repro.exp.validate runs each for 2 steps)
SPECS = {"mc_dsgt_drop20": _spec("mc_dsgt", 0.2),
         "dsgd_ideal": _spec("dsgd", 0.0)}


def median(vals):
    vals = [v for v in vals if v is not None]
    return float(np.median(vals)) if vals else None


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N}  random-waypoint mobility (radius=0.45)  "
              f"non-iid Dirichlet(0.3) data  budget T={T}")
    final = {}
    for drop in DROPS:
        for name in _ALGOS:
            res = exp.run(_spec(name, drop), quiet=con.quiet)
            telem = res.telemetry  # created by run(): mobility => recorder
            g = float(res.history[-1][1])
            gap = median([e["spectral_gap"] for e in telem.history])
            diam = median([e["eff_diameter"] for e in telem.history])
            last = telem.history[-1]
            empty = last["kinds"].get("empty", 0)
            con.event("result", algo=name, drop=drop, grad_sq=g,
                      consensus=last["consensus"], spectral_gap=gap,
                      eff_diameter=(diam if diam is not None
                                    else float("nan")),
                      dropped=empty,
                      window=last["window"][1] - last["window"][0])
            final[(name, drop)] = g

    con.print("\nGradient tracking survives the lossy channel: at 20% and "
              "40% link drop the tracked runs (mc_dsgt, gt_local) keep "
              "converging while plain DSGD pays the full heterogeneity "
              "bias; the realized effective diameter and spectral gap "
              "quantify exactly how much mixing the channel destroyed.")
    assert final[("mc_dsgt", 0.4)] < final[("mc_dsgt", 0.0)] * 50, \
        "MC-DSGT should degrade gracefully under 40% loss"
    return final


if __name__ == "__main__":
    main()
