"""Wireless mobility + lossy channels: the paper's motivating scenario.

"Decentralized algorithms are more robust in wireless scenarios especially
when nodes are moving" — this example builds that scenario with
`repro.sim`: 16 nodes move through the unit square (random-waypoint
mobility, unit-disk links), the channel drops an increasing fraction of
links per round (iid Bernoulli), the surviving links are repaired into a
valid mixing matrix, and MC-DSGT / DSGD / gt_local run over the *realized*
schedule while the telemetry recorder measures what the faults did to
mixing (windowed spectral gap, empirical effective diameter of the
realized rounds, consensus distance).

    PYTHONPATH=src python examples/wireless_mobility.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, gossip
from repro.data import logreg_dataset_dirichlet, logreg_loss_and_grad
from repro.sim import (BernoulliDropChannel, TelemetryRecorder,
                       random_waypoint_schedule, realize_weight_schedule)


def median(vals):
    vals = [v for v in vals if v is not None]
    return float(np.median(vals)) if vals else None


def main():
    n, d, m = 16, 64, 256
    T = 320                    # gossip/oracle budget per run
    R = 2                      # MC-DSGT consensus/accumulation rounds
    radius = 0.45

    H, y = logreg_dataset_dirichlet(n, m, d, alpha=0.3, seed=0)
    _, _, stoch, _, gnorm2 = logreg_loss_and_grad(rho=0.1)
    x0 = jnp.zeros((n, d))

    def grad_fn(xs, key):
        return stoch(xs, H, y, key, 16)

    def eval_fn(xb):
        return gnorm2(xb, H, y)

    mobility = random_waypoint_schedule(n, radius=radius, seed=0)
    ideal = gossip.schedule_from_topology(mobility, horizon=T + 8)

    algos = [
        ("mc_dsgt", lambda: alg.mc_dsgt(0.3, R=R)),
        ("gt_local", lambda: alg.gt_local(0.2)),
        ("dsgd", lambda: alg.dsgd(0.3)),
    ]
    print(f"n={n}  random-waypoint mobility (radius={radius})  "
          f"non-iid Dirichlet(0.3) data  budget T={T}")
    print(f"{'algo':9s} {'drop':>5s} {'||grad f(x_bar)||^2':>20s} "
          f"{'consensus':>10s} {'gap~':>7s} {'eff_diam~':>9s} "
          f"{'dropped rounds':>14s}")
    final = {}
    for drop in (0.0, 0.2, 0.4):
        sched = ideal if drop == 0.0 else realize_weight_schedule(
            ideal, [BernoulliDropChannel(drop, seed=7)], rounds=T + 8)
        for name, mk in algos:
            algo = mk()
            steps = max(2, T // algo.weights_per_step)
            telem = TelemetryRecorder(sched, wps=algo.weights_per_step)
            _, hist = alg.run(algo, x0, grad_fn, sched, steps,
                              jax.random.key(0), eval_fn=eval_fn,
                              eval_every=max(1, steps - 1),
                              telemetry=telem)
            g = float(hist[-1][1])
            gap = median([e["spectral_gap"] for e in telem.history])
            diam = median([e["eff_diameter"] for e in telem.history])
            empty = sum(e["kinds"].get("empty", 0) for e in telem.history[-1:])
            last = telem.history[-1]
            print(f"{name:9s} {drop:5.1f} {g:20.6f} "
                  f"{last['consensus']:10.4f} {gap:7.3f} "
                  f"{diam if diam is not None else float('nan'):9.1f} "
                  f"{empty:8d}/{last['window'][1] - last['window'][0]} "
                  f"(last window)")
            final[(name, drop)] = g

    print("\nGradient tracking survives the lossy channel: at 20% and 40% "
          "link drop the tracked runs (mc_dsgt, gt_local) keep converging "
          "while plain DSGD pays the full heterogeneity bias; the realized "
          "effective diameter and spectral gap quantify exactly how much "
          "mixing the channel destroyed.")
    assert final[("mc_dsgt", 0.4)] < final[("mc_dsgt", 0.0)] * 50, \
        "MC-DSGT should degrade gracefully under 40% loss"
    return final


if __name__ == "__main__":
    main()
