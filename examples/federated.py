"""Federated learning as decentralized optimization over a time-varying
network (paper §1: FedAvg = alternating local updates and global averaging).

The federated schedule is `local_steps` rounds of the self-loop-only graph
followed by one complete-graph round; running DSGD over it IS local-SGD /
FedAvg.  Compares against the always-connected and sun-shaped schedules at
equal communication budget (communication happens only on non-identity
rounds, so the federated run 'pays' 1/(local_steps+1) of the comm cost).

    PYTHONPATH=src python examples/federated.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import driver, gossip, topology as topo
from repro.data import (logreg_dataset, logreg_dataset_dirichlet,
                        logreg_loss_and_grad)


def main():
    n, d, m = 16, 64, 256
    T = 480
    H, y = logreg_dataset(n, m, d, seed=0)
    _, _, stoch, _, gnorm2 = logreg_loss_and_grad(rho=0.1)
    x0 = jnp.zeros((n, d))

    def grad_fn(xs, key):
        return stoch(xs, H, y, key, 16)

    def eval_fn(xb):
        return gnorm2(xb, H, y)

    schedules = {
        "fedavg(local=4)": gossip.schedule_from_topology(
            topo.federated_schedule(n, local_steps=4)),
        "fedavg(local=16)": gossip.schedule_from_topology(
            topo.federated_schedule(n, local_steps=16)),
        "complete": gossip.WeightSchedule((np.ones((n, n)) / n,)),
        "sun(beta=1-1/n)": gossip.theorem3_weight_schedule(n, 1 - 1 / n),
    }
    print(f"n={n}  budget T={T}  DSGD with gamma=0.4 over each schedule")
    print(f"{'schedule':18s} {'final ||grad f(x_bar)||^2':>26s} "
          f"{'comm rounds':>12s}  gossip plan (one period)")
    for name, sched in schedules.items():
        _, hist = alg.run(alg.dsgd(0.4), x0, grad_fn, sched, T,
                          jax.random.key(0), eval_fn=eval_fn, eval_every=T - 1)
        # the gossip plan names each round's lowering; `empty` rounds are
        # the local steps — the auto dispatcher skips them entirely, so
        # FedAvg's saved communication is visible in the plan itself
        plan = sched.plan()
        comm = sum(1 for rd in plan.rounds if rd.kind != "empty") \
            * (T // plan.period)
        kinds = "+".join(f"{plan.kinds.count(k)}x{k}"
                         for k in dict.fromkeys(plan.kinds))
        print(f"{name:18s} {float(hist[-1][1]):26.6f} {comm:12d}  {kinds}")
    print("\nFedAvg trades convergence for (local_steps+1)x less "
          "communication — the time-varying-network view makes that a "
          "topology choice, not a different algorithm, and the gossip plan "
          "lowers each phase to its cheapest collective (empty rounds: "
          "none; the averaging round: one all-reduce).")

    # The engine's federated update-rule family on Dirichlet(0.1) non-iid
    # data: local_sgd is FedAvg proper (mix, then local step), gt_local
    # adds a gradient tracker that keeps tracking through the local-only
    # rounds — the heterogeneity correction FedAvg lacks.
    Hh, yh = logreg_dataset_dirichlet(n, m, d, alpha=0.1, seed=0)

    def grad_h(xs, key):
        return stoch(xs, Hh, yh, key, 16)

    fed = gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    print(f"\nDirichlet(alpha=0.1) label-skew partition, fedavg(local=4), "
          f"budget T={T}:")
    for name, algo in [("local_sgd", alg.local_sgd(0.4)),
                       ("gt_local", alg.gt_local(0.2)),
                       ("dsgd", alg.dsgd(0.4))]:
        _, hist = driver.run_algorithm(
            algo, x0, grad_h, fed, T // algo.weights_per_step,
            jax.random.key(0), eval_fn=lambda xb: gnorm2(xb, Hh, yh),
            eval_every=T - 1)
        print(f"  {name:10s} final ||grad f(x_bar)||^2 = "
              f"{float(hist[-1][1]):.6f}")


if __name__ == "__main__":
    main()
