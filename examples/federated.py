"""Federated learning as decentralized optimization over a time-varying
network (paper §1: FedAvg = alternating local updates and global averaging).

The federated schedule is `local_steps` rounds of the self-loop-only graph
followed by one complete-graph round; running DSGD over it IS local-SGD /
FedAvg.  Every scenario here is one :class:`repro.exp.ExperimentSpec`
literal — the schedule choice, the Dirichlet heterogeneity, and the update
rule are all spec fields, and ``repro.exp.build`` exposes the gossip plan
that shows exactly where FedAvg's communication savings come from.

    PYTHONPATH=src python examples/federated.py
"""

from repro import exp
from repro.obs import Console

N = 16
T = 480

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="logreg", d=64, m=256, rho=0.1),
    data=exp.DataSpec(batch=16),
    algorithm=exp.AlgorithmSpec(name="dsgd", gamma=0.4),
    run=exp.RunSpec(nodes=N, steps=T, eval_every=T - 1),
)

# one DSGD run per schedule family, at equal total round budget
SCHEDULE_SPECS = {
    "fedavg(local=4)": exp.with_overrides(_BASE, {
        "topology.kind": "federated", "topology.local_steps": 4}),
    "fedavg(local=16)": exp.with_overrides(_BASE, {
        "topology.kind": "federated", "topology.local_steps": 16}),
    "complete": exp.with_field(_BASE, "topology.kind", "complete"),
    "sun(beta=1-1/n)": exp.with_overrides(_BASE, {
        "topology.kind": "sun", "topology.beta": 1 - 1 / N}),
}

# the engine's federated rule family on Dirichlet(0.1) non-iid data
_FED = exp.with_overrides(_BASE, {
    "topology.kind": "federated", "topology.local_steps": 4,
    "data.hetero_alpha": 0.1})
RULE_SPECS = {
    "local_sgd": exp.with_overrides(_FED, {
        "algorithm.name": "local_sgd", "algorithm.gamma": 0.4}),
    "gt_local": exp.with_overrides(_FED, {
        "algorithm.name": "gt_local", "algorithm.gamma": 0.2}),
    "dsgd": _FED,
}

# the CI spec-smoke pool (repro.exp.validate runs each for 2 steps)
SPECS = {"fedavg4_dsgd": SCHEDULE_SPECS["fedavg(local=4)"],
         "dirichlet_local_sgd": RULE_SPECS["local_sgd"],
         "dirichlet_gt_local": RULE_SPECS["gt_local"]}


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N}  budget T={T}  DSGD with gamma=0.4 over each schedule")
    for name, spec in SCHEDULE_SPECS.items():
        res = exp.run(spec, quiet=con.quiet)
        # the gossip plan names each round's lowering; `empty` rounds are
        # the local steps — the auto dispatcher skips them entirely, so
        # FedAvg's saved communication is visible in the plan itself
        plan = res.built.schedule.plan()
        comm = sum(1 for rd in plan.rounds if rd.kind != "empty") \
            * (T // plan.period)
        kinds = "+".join(f"{plan.kinds.count(k)}x{k}"
                         for k in dict.fromkeys(plan.kinds))
        con.event("schedule_result", schedule=name,
                  grad_sq=float(res.history[-1][1]), comm_rounds=comm,
                  plan=kinds)
    con.print("\nFedAvg trades convergence for (local_steps+1)x less "
              "communication — the time-varying-network view makes that a "
              "topology choice, not a different algorithm, and the gossip "
              "plan lowers each phase to its cheapest collective (empty "
              "rounds: none; the averaging round: one all-reduce).")

    # local_sgd is FedAvg proper (mix, then local step); gt_local adds a
    # gradient tracker that keeps tracking through the local-only rounds —
    # the heterogeneity correction FedAvg lacks.
    con.print(f"\nDirichlet(alpha=0.1) label-skew partition, "
              f"fedavg(local=4), budget T={T}:")
    for name, spec in RULE_SPECS.items():
        res = exp.run(spec, quiet=con.quiet)
        con.event("rule_result", rule=name,
                  grad_sq=float(res.history[-1][1]))


if __name__ == "__main__":
    main()
