"""Client sampling at 100k nodes: the sparse scenario engine end to end.

The paper's complexity statement is asymptotic in n, but dense (n, n)
mixing matrices cap any empirical check near a few thousand nodes.  This
example runs the regime the sparse engine exists for: ``n = 100_000``
devices of which a ``sample_k = 256`` cohort wakes up each round, moves
through the unit square (hashed waypoint mobility), gossips over the
unit-disk graph among the cohort with Metropolis weights, and loses links
to an iid drop channel plus node churn.  Everything stays in edge-list
form — the schedule is a :class:`repro.sparse.SparseWeightSchedule`, the
plan a :class:`repro.sparse.SparseGossipPlan`, faults are per-edge hash
streams, and telemetry (consensus, windowed spectral-gap proxy,
bytes/round over participating senders) never materializes a matrix.
Per-round cost is O(edges) ~ O(sample_k^2), independent of n.

    PYTHONPATH=src python examples/sampled_clients.py

The run writes mixing telemetry plus a reproducibility manifest
(``sampled_clients_100k.telemetry.json{,.spec.json}``); the checked-in
copy lives at ``experiments/manifests/sampled_clients_100k.json``.
"""

import numpy as np

from repro import exp
from repro.obs import Console

N = 100_000               # devices in the fleet
K = 256                   # cohort sampled per round
STEPS = 5
TELEMETRY = "sampled_clients_100k.telemetry.json"

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="logreg", d=8, m=8, rho=0.1),
    data=exp.DataSpec(batch=4),
    algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=0.3, R=2),
    topology=exp.TopologySpec(kind="random-sampled", sample_k=K,
                              radius=0.45),
    channel=exp.ChannelSpec(link_drop=0.2, churn=0.02),
    run=exp.RunSpec(steps=STEPS, nodes=N, gossip_impl="auto",
                    eval_every=STEPS, telemetry=TELEMETRY),
)

# the CI spec-smoke pool (repro.exp.validate runs each for 2 steps):
# a 1k-node cohort-sampled cell on both host paths — 'auto' stays in edge
# form, 'dense' materializes the same rounds and must agree
SPECS = {
    "sampled_auto": exp.with_overrides(_BASE, {
        "run.nodes": 1000, "run.telemetry": None,
        "topology.sample_k": 32}),
    "sampled_host_dense": exp.with_overrides(_BASE, {
        "run.nodes": 1000, "run.telemetry": None,
        "run.gossip_impl": "dense", "topology.sample_k": 32}),
}


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N:,}  sample_k={K}  waypoint mobility (radius=0.45)  "
              f"20% link drop + 2% churn  mc_dsgt R=2")
    res = exp.run(_BASE, quiet=con.quiet)
    realized = res.built.realized
    epr = realized["edges_per_round"]
    con.event("realized", nodes=N, sample_k=K,
              edges_per_round=epr, senders_per_round=
              realized["senders_per_round"], period=realized["period"])
    last = res.telemetry.history[-1]
    g = float(res.history[-1][1])
    con.event("result", grad_sq=g, consensus=last["consensus"],
              spectral_gap=last["spectral_gap"],
              bytes_total=res.telemetry.bytes_total)

    # the point of the engine: realized work is O(edges), not O(n^2) —
    # the densest round touches ~k(k-1) directed edges, 6 orders of
    # magnitude below the n^2 a dense round would carry
    assert epr["max"] <= K * (K - 1), epr
    assert epr["max"] < N, epr
    assert np.isfinite(g), g
    con.print(f"\n{N:,} nodes mixed through edge lists only: the densest "
              f"round carried {epr['max']:,} directed edges "
              f"({epr['max'] / (N * (N - 1)):.2e} of dense n^2), telemetry "
              f"counted bytes over participating senders, and the manifest "
              f"({TELEMETRY}.spec.json) records the realized edge counts.")
    return res


if __name__ == "__main__":
    main()
