"""Quickstart: decentralized non-convex optimization over a time-varying
sun-shaped network — DSGD vs DSGT vs MC-DSGT (paper Table 1 in miniature).

Runs the paper's §6 objective (logistic regression + non-convex regularizer)
on synthetic heterogeneous data and prints the global gradient norm
||∇f(x̄)||² per oracle/communication budget T for all three algorithms.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import driver, gossip
from repro.data import logreg_dataset, logreg_loss_and_grad


def main():
    n, d, m = 16, 64, 256
    beta = 1 - 1 / n          # worst connectivity Theorem 3 allows
    R = 4                     # MC-DSGT consensus/accumulation rounds
    T_budget = 960            # total gossip+oracle rounds per node
    gamma = 0.4
    batch = 16

    H, y = logreg_dataset(n, m, d, seed=0)
    loss_i, full_grad, stoch_grad, global_loss, gnorm2 = \
        logreg_loss_and_grad(rho=0.1)
    sched = gossip.theorem3_weight_schedule(n, beta)
    x0 = jnp.zeros((n, d))

    def grad_fn(xs, key):
        return stoch_grad(xs, H, y, key, batch)

    def eval_fn(xbar):
        return gnorm2(xbar, H, y)

    print(f"n={n} beta={beta:.4f} (sun-shaped, rotating centers, "
          f"|C|={max(1, int(n * (1 - beta)))})  budget T={T_budget}")
    print(f"{'algo':10s} {'T':>6s} {'||grad f(x_bar)||^2':>22s}")
    results = {}
    # every algorithm is one engine UpdateRule driven by the unified
    # repro.core.driver loop — same staging/loop as the distributed CLI
    for name, algo, steps in [
        ("dsgd", alg.dsgd(gamma), T_budget),
        ("dsgt", alg.dsgt(gamma), T_budget // 2),
        ("mc_dsgt", alg.mc_dsgt(gamma, R=R), T_budget // (2 * R)),
    ]:
        state, hist = driver.run_algorithm(algo, x0, grad_fn, sched, steps,
                                           jax.random.key(0), eval_fn=eval_fn,
                                           eval_every=max(1, steps // 8))
        for t, g in hist[-1:]:
            print(f"{name:10s} {t:6d} {float(g):22.6f}")
        results[name] = float(hist[-1][1])

    assert results["mc_dsgt"] <= results["dsgd"], \
        "MC-DSGT should dominate DSGD on a poorly-connected graph"
    print("\nMC-DSGT <= DSGD at equal budget: paper Table 1 ordering holds.")
    return results


if __name__ == "__main__":
    main()
