"""Quickstart: decentralized non-convex optimization over a time-varying
sun-shaped network — DSGD vs DSGT vs MC-DSGT (paper Table 1 in miniature).

Each run is ONE declarative :class:`repro.exp.ExperimentSpec` literal (the
paper's §6 objective on synthetic heterogeneous data, sun-shaped schedule
at the worst connectivity Theorem 3 allows) executed through
``repro.exp.run`` — the same entry point as the training CLI.  Prints the
global gradient norm ||∇f(x̄)||² per oracle/communication budget T.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro import exp
from repro.obs import Console

N = 16
BETA = 1 - 1 / N          # worst connectivity Theorem 3 allows
R = 4                     # MC-DSGT consensus/accumulation rounds
T_BUDGET = 960            # total gossip+oracle rounds per node
GAMMA = 0.4

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="logreg", d=64, m=256, rho=0.1),
    data=exp.DataSpec(batch=16),
    topology=exp.TopologySpec(kind="sun", beta=BETA),
)


def _spec(algo: str, steps: int, R: int = 1) -> exp.ExperimentSpec:
    return dataclasses.replace(
        _BASE,
        algorithm=exp.AlgorithmSpec(name=algo, gamma=GAMMA, R=R),
        run=exp.RunSpec(nodes=N, steps=steps,
                        eval_every=max(1, steps // 8)))


# Equal budget T: each algorithm gets T / weights_per_step steps.
SPECS = {
    "dsgd": _spec("dsgd", T_BUDGET),
    "dsgt": _spec("dsgt", T_BUDGET // 2),
    "mc_dsgt": _spec("mc_dsgt", T_BUDGET // (2 * R), R=R),
}


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N} beta={BETA:.4f} (sun-shaped, rotating centers, "
              f"|C|={max(1, int(N * (1 - BETA)))})  budget T={T_BUDGET}")
    results = {}
    for name, spec in SPECS.items():
        res = exp.run(spec, quiet=con.quiet)
        t, g = res.history[-1]
        con.event("result", algo=name, T=int(t), grad_sq=float(g))
        results[name] = float(g)

    assert results["mc_dsgt"] <= results["dsgd"], \
        "MC-DSGT should dominate DSGD on a poorly-connected graph"
    con.print("\nMC-DSGT <= DSGD at equal budget: paper Table 1 "
              "ordering holds.")
    return results


if __name__ == "__main__":
    main()
