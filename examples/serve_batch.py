"""Batched-serving example: train a small fleet, then continuous-batch
decode on a reduced SSM model (state-space decode is O(1) in context
length — the serve-path showcase).

    PYTHONPATH=src python examples/serve_batch.py --arch falcon-mamba-7b
"""

import argparse

from repro.launch.serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args(argv)
    return serve_main(["--arch", args.arch, "--preset", "reduced",
                       "--nodes", "4", "--steps", "3",
                       "--requests", "16", "--serve-batch", str(args.batch),
                       "--prompt-len", "48", "--max-new", "16"])


if __name__ == "__main__":
    main()
