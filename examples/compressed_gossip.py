"""Compressed gossip under wireless mobility: same convergence, ~1/8 wire.

The compression axis is one spec section: ``exp.sweep`` expands the base
wireless scenario (16 moving nodes, unit-disk links, 20% per-link drop,
non-iid Dirichlet data) over ``compression.scheme`` in {none, sign, int8}
and runs MC-DSGT with error feedback over each.  Everything else — the
mobility model, channel repair, the fused quantize->mix->dequantize
window, and the bytes/round telemetry this example prints — comes from
``exp.run(spec)``.

    PYTHONPATH=src python examples/compressed_gossip.py
"""

from repro import exp
from repro.obs import Console

N = 16
T = 240                    # gossip/oracle budget per run
SCHEMES = exp.COMPRESSIONS  # ("none", "sign", "int8")

_BASE = exp.ExperimentSpec(
    model=exp.ModelRef(kind="logreg", d=64, m=256, rho=0.1),
    data=exp.DataSpec(batch=16, hetero_alpha=0.3),
    topology=exp.TopologySpec(kind="waypoint-mobility", radius=0.45),
    algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=0.3, R=2),
    channel=exp.ChannelSpec(link_drop=0.2),
    compression=exp.CompressionSpec(warmup=4, group=64),
    run=exp.RunSpec(nodes=N),
)


def _specs() -> dict:
    steps = max(2, T // exp.weights_per_step(_BASE.algorithm))
    base = exp.with_overrides(_BASE, {
        "run.steps": steps, "run.eval_every": max(1, steps - 1)})
    return dict(zip(SCHEMES,
                    exp.sweep(base, {"compression.scheme": list(SCHEMES)})))


# the CI spec-smoke pool (repro.exp.validate runs each for 2 steps)
SPECS = {f"compressed_{s}": spec for s, spec in _specs().items()
         if s != "none"}


def main(con: Console = None):
    con = con or Console.from_argv()
    con.print(f"n={N}  waypoint mobility (radius=0.45)  20% link drop  "
              f"non-iid Dirichlet(0.3)  mc_dsgt R=2 + error feedback  "
              f"budget T={T}")
    results = {}
    for scheme, spec in _specs().items():
        res = exp.run(spec, quiet=con.quiet)
        telem = res.telemetry  # created by run(): mobility/compression
        grad_sq = float(res.history[-1][1])
        mb = telem.bytes_total / 1e6
        rc = res.built.realized["compression"]
        con.event("result", scheme=scheme, grad_sq=grad_sq, wire_mb=mb,
                  bytes_per_round=rc["bytes_per_round"],
                  consensus=telem.history[-1]["consensus"])
        results[scheme] = (grad_sq, mb)

    mb_none = results["none"][1]
    con.print("\nSame recipe, a fraction of the traffic: sign sends "
              f"{results['sign'][1] / mb_none:.1%} and int8 "
              f"{results['int8'][1] / mb_none:.1%} of the uncompressed "
              "volume, and the error-feedback residual keeps the quantized "
              "runs converging through the lossy, time-varying links.")
    assert results["sign"][1] < 0.2 * mb_none, \
        "sign compression should cut wire volume by >5x"
    assert results["int8"][1] < 0.5 * mb_none, \
        "int8 compression should cut wire volume by >2x"
    return results


if __name__ == "__main__":
    main()
