"""Lower-bound demo (Theorem 4, Instance 2): on the adversarial sun-shaped
schedule with the odd/even zero-chain split, ANY gossip algorithm's progress
prog(x) is capped at ~ C (1-beta) T — watch DSGT hit the wall.

    PYTHONPATH=src python examples/lower_bound_demo.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import gossip, lower_bound as lb, topology as topo
from repro.obs import Console


def main(con: Console = None):
    con = con or Console.from_argv()
    n, beta, T = 16, 1 - 1 / 16, 96
    inst = lb.make_instance2(L=1.0, Delta=10.0, n=n, beta=beta, T=T)
    I1, I2 = inst.set1, inst.set2
    sched_graphs = topo.sun_shaped_schedule(n, beta, avoid=I1 + I2)
    dist = topo.effective_distance(sched_graphs, I1, I2,
                                   period=sched_graphs.period)
    wsched = gossip.theorem3_weight_schedule(n, beta, avoid=I1 + I2)

    con.print(f"n={n} beta={beta:.4f}  effective distance(I1, I2) = {dist}")
    con.print(f"zero-chain dim d = {inst.d}; theory cap on prog ~ "
              f"T/dist + 1 = {T // dist + 1}")

    def grad_fn(xs, key):
        return inst.grad_stacked(xs)  # lossless oracle (Instance 2 uses full grads)

    algo = alg.dsgt(gamma=0.3)
    state = algo.init(jnp.zeros((n, inst.d)))
    state = alg.warm_start(algo, state, grad_fn, jax.random.key(0))
    step = jax.jit(algo.step, static_argnums=1)
    t = 0
    for k in range(T // 2):
        Ws = jnp.asarray(wsched.stacked(t, 2))
        state = step(state, grad_fn, Ws, jax.random.key(k))
        t += 2
        if (k + 1) % 8 == 0:
            progs = [int(lb.prog(state.x[i])) for i in range(n)]
            cap = t // dist + 1
            con.event("progress", round=k + 1, T=t, max_prog=max(progs),
                      cap=cap)
            assert max(progs) <= cap + 1, "progress exceeded the lower-bound cap!"
    con.print("\nprog(x) stayed within the Theorem 4 "
              "information-propagation cap.")


if __name__ == "__main__":
    main()
