"""Minitron-4B: width/depth-pruned Nemotron-4 (squared-ReLU, GQA)
[arXiv:2407.14679]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    mlp_act="relu2",
    tie_embeddings=False,
    source="arXiv:2407.14679",
))
