"""InternVL2-1B backbone: InternLM2-chat-1.8B-style language model consuming
InternViT patch embeddings via the stub frontend [arXiv:2404.16821]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    pattern=("attn",),
    mlp_act="swiglu",
    frontend="vision",
    frontend_tokens=256,          # ViT patches after pixel-shuffle projector
    source="arXiv:2404.16821",
))
