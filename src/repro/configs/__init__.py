"""Architecture config registry: resolve --arch <id> to a ModelConfig."""
from .base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def names() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (  # noqa: F401
        falcon_mamba_7b,
        granite_moe_3b_a800m,
        internvl2_1b,
        llama4_maverick_400b_a17b,
        logreg_paper,
        minitron_4b,
        nemotron_4_340b,
        qwen1_5_0_5b,
        recurrentgemma_2b,
        whisper_tiny,
        yi_6b,
    )
