"""Whisper-tiny: 4+4 encoder-decoder, conv frontend stubbed to frame
embeddings [arXiv:2212.04356]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,                 # decoder layers
    encoder_layers=4,
    encoder_seq=1500,             # 30 s of audio after the conv frontend
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    norm="layernorm",
    mlp_act="gelu",
    rope_theta=0.0,               # absolute positions, no rope
    frontend="audio",
    source="arXiv:2212.04356",
))
