"""Nemotron-4-340B: dense GQA with squared-ReLU MLP, untied embeddings
[arXiv:2402.16819]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    mlp_act="relu2",
    tie_embeddings=False,
    source="arXiv:2402.16819",
))
