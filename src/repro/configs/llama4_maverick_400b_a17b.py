"""Llama-4-Maverick-400B-A17B: alternating dense/MoE layers, 128 routed
experts top-1 + shared expert, early-fusion multimodal (text backbone here)
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    pattern=("attn", "moe"),      # interleaved MoE every other layer
    num_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    shared_expert=True,
    mlp_act="swiglu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
