"""Yi-6B: llama-architecture dense GQA [arXiv:2403.04652]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    mlp_act="swiglu",
    source="arXiv:2403.04652",
))
