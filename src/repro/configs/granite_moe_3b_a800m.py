"""Granite-3.0-3B-A800M MoE: 40 experts top-8, small expert hidden dim
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                     # expert hidden dim
    vocab_size=49_155,
    pattern=("moe",),
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    mlp_act="swiglu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
