"""Qwen1.5-0.5B: dense GQA(=MHA) with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    mlp_act="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B",
))
