"""The paper's own experiment (Section 6): logistic regression with the
non-convex regularizer r(x) = sum_k x_k^2 / (1 + x_k^2) on heterogeneously
partitioned binary datasets.  Not an LM config — consumed by
benchmarks/figure2.py and examples/paper_figure2.py."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LogRegConfig:
    name: str
    n_nodes: int
    center_size: int          # |C| of the sun-shaped schedule
    rho: float                # regularization weight
    R: int                    # MC-DSGT consensus/accumulation rounds
    d: int                    # feature dim
    m: int                    # samples per node
    batch: int = 32


MNIST = LogRegConfig(name="mnist-24", n_nodes=16, center_size=1, rho=0.2,
                     R=2, d=784, m=512)
COVTYPE = LogRegConfig(name="covtype-binary", n_nodes=32, center_size=4,
                       rho=0.015, R=4, d=54, m=512)
