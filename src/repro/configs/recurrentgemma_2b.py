"""RecurrentGemma-2B: RG-LRU recurrent blocks + local attention, 2:1 pattern
[arXiv:2402.19427]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,                # 8 full (rglru, rglru, attn) units + 2 rglru
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,               # MQA in the local-attention layers
    d_ff=7680,
    vocab_size=256_000,
    pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    conv_width=4,
    window=2048,                  # local attention window
    mlp_act="geglu",
    source="arXiv:2402.19427",
))
