"""Falcon-Mamba-7B: pure mamba1 stack, attention-free [arXiv:2410.05355]."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                       # no separate MLP; mamba block only
    vocab_size=65_024,
    pattern=("mamba",),
    ssm_state=16,
    ssm_expand=2,
    conv_width=4,
    source="arXiv:2410.05355",
))
