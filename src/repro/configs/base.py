"""Model / run configuration schema.

Every assigned architecture gets one ``ModelConfig`` in its own module under
``repro.configs``; the registry in ``__init__`` resolves ``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # layer pattern: one scan unit; num_layers = units * len(pattern) + rem
    # kinds: 'attn' (dense MLP), 'moe' (MoE MLP), 'mamba', 'rglru'
    pattern: Tuple[str, ...] = ("attn",)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # expert hidden dim (0 -> d_ff)
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    moe_seq_group: int = 0         # >0: dispatch per token-group (perf opt)
    prefill_last_only: bool = False  # perf opt: unembed only the last position
    attn_shard_fallback: str = "head_dim"  # when H % model_ways != 0:
                                   # 'head_dim' (baseline) | 'replicate' (perf:
                                   # avoids the scores psum over sharded hd)
    moe_pad_experts: int = 0       # pad expert count to this (perf: enables
                                   # expert-parallel sharding when E doesn't
                                   # divide the model axis)

    # SSM (mamba1)
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)

    # hybrid (RG-LRU)
    lru_width: int = 0             # 0 -> d_model

    # attention details
    window: int = 0                # sliding window (0 = full causal)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0

    # MLP / norms
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = True

    # enc-dec (whisper): encoder layers with cross-attention in the decoder
    encoder_layers: int = 0
    encoder_seq: int = 1500        # stub frame count (whisper-tiny 30 s)

    # modality frontend stub: '' | 'vision' | 'audio'
    frontend: str = ""
    frontend_tokens: int = 0       # patch/frame embeddings per sample

    # numerics
    dtype: str = "bfloat16"        # activation / param dtype for dry-run
    source: str = ""               # citation

    use_pallas: bool = False       # route attention through the Pallas
                                   # kernels (TPU; interpret=True on CPU)

    # lowering controls (cost-probe mode unrolls every scan so XLA's
    # HloCostAnalysis counts each layer/round; see launch/dryrun.py)
    unroll: bool = False
    q_chunk: int = 1024            # attention query-chunk (lax.map) size
    scan_chunk: int = 64           # linear-recurrence chunk size

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type == "ssm" and not self.dt_rank:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.arch_type == "moe" and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.arch_type == "hybrid" and not self.lru_width:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def units_and_rem(self) -> tuple:
        k = len(self.pattern)
        return self.num_layers // k, self.num_layers % k

    def reduced(self, layers: int = 2, d_model: int = 256, d_ff: int = 512,
                experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (the contract:
        <=2 layers-ish, d_model <= 512, <= 4 experts)."""
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kvh = min(self.num_kv_heads, heads) if heads else 0
        if heads:
            kvh = max(1, kvh)
            # keep the GQA ratio flavour: kv strictly less than q if original had GQA
            if self.num_kv_heads < self.num_heads and heads > 1:
                kvh = max(1, heads // 2)
        k = len(self.pattern)
        nl = max(layers, k)          # at least one full pattern unit
        nl = (nl // k) * k if nl % k == 0 else nl
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=nl,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=(d_model // heads if heads else 0),
            d_ff=d_ff,
            moe_d_ff=(d_ff if self.num_experts else 0),
            vocab_size=vocab,
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            experts_per_token=(min(self.experts_per_token, min(self.num_experts, experts))
                               if self.num_experts else 0),
            moe_capacity_factor=64.0,  # dropless at smoke scale
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            dt_rank=(-(-d_model // 16) if self.arch_type == "ssm" else 0),
            lru_width=(d_model if self.arch_type == "hybrid" else 0),
            window=min(self.window, 64) if self.window else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 32),
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
