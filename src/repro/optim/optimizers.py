"""Minimal optimizer transforms (optax-style init/update pairs) used as the
*local* update rule inside the decentralized algorithms.  The paper's
MC-DSGT uses plain gamma * h; momentum/adam are framework extensions."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any], tuple]  # (grads, state) -> (updates, state)


def sgd() -> Optimizer:
    return Optimizer(lambda p: None, lambda g, s: (g, s))


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, m):
        m = jax.tree.map(lambda mm, g: beta * mm + g, m, grads)
        return m, m

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, s):
        t = s["t"] + 1
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, s["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, s["v"], grads)
        mh = jax.tree.map(lambda mm: mm / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - b2 ** t), v)
        upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + eps), mh, vh)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)
