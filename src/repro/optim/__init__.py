from .optimizers import Optimizer, adam, momentum, sgd  # noqa: F401
