"""Sparse gossip segment-sum Pallas TPU kernel.

Computes ``delta[s] = sum_{e: seg[e] == s} w[e] * (xs[e] - xd[e])`` — the
per-receiver update of one edge-list gossip round (Laplacian form, see
:mod:`repro.sparse.plan`).  TPUs have no native scatter-add in VMEM, so
the segment sum is expressed as an MXU matmul: each edge chunk builds a
(S, be) one-hot matrix from its segment ids (``broadcasted_iota`` against
the seg block — TPU requires >= 2-D iota) and multiplies it into the
(be, bd) weighted edge differences, accumulating (S, bd) output tiles
across edge chunks.  S is the *compacted* receiver count (at most the
sampled cohort size k, not n), so the output tile stays in VMEM while
edges stream through.

Padded edges carry ``w = 0`` and contribute exactly zero, so callers may
pad E freely to the block size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import resolve_interpret


def _kernel(seg_ref, w_ref, xs_ref, xd_ref, o_ref, *, num_segments):
    e = pl.program_id(1)
    seg = seg_ref[0, :]                       # (be,) int32
    w = w_ref[0, :].astype(jnp.float32)       # (be,)
    xs = xs_ref[...].astype(jnp.float32)      # (be, bd)
    xd = xd_ref[...].astype(jnp.float32)
    contrib = w[:, None] * (xs - xd)          # (be, bd)
    ids = jax.lax.broadcasted_iota(jnp.int32, (num_segments, seg.shape[0]), 0)
    onehot = (ids == seg[None, :]).astype(jnp.float32)  # (S, be)
    acc = jax.lax.dot_general(onehot, contrib, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(e != 0)
    def _accum():
        o_ref[...] += acc


def sparse_segment_mix(seg, w, xs, xd, *, num_segments, block_e=512,
                       block_d=512, interpret="auto"):
    """seg, w: (E,); xs, xd: (E, D) -> (num_segments, D) float32 delta.

    E must be a multiple of ``block_e`` and D of ``block_d`` (the ops
    wrapper pads); num_segments should respect the f32 sublane tile
    (multiple of 8) for compiled TPU runs.
    """
    E, D = xs.shape
    be = min(block_e, E)
    bd = min(block_d, D)
    assert E % be == 0 and D % bd == 0, (E, be, D, bd)
    kernel = functools.partial(_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(D // bd, E // be),
        in_specs=[
            pl.BlockSpec((1, be), lambda d, e: (0, e)),
            pl.BlockSpec((1, be), lambda d, e: (0, e)),
            pl.BlockSpec((be, bd), lambda d, e: (e, d)),
            pl.BlockSpec((be, bd), lambda d, e: (e, d)),
        ],
        out_specs=pl.BlockSpec((num_segments, bd), lambda d, e: (0, d)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(seg.reshape(1, E), w.reshape(1, E), xs, xd)
