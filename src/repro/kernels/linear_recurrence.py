"""Chunked diagonal linear recurrence Pallas TPU kernel.

Computes h_t = a_t * h_{t-1} + b_t along the time axis for flattened channel
blocks.  Serves both sequence mixers of the assigned architectures:

* mamba1 selective scan (channels = d_inner * ssm_state), and
* RG-LRU (channels = lru_width).

Grid: (batch, channel_blocks, time_chunks) — the time axis is innermost /
sequential, carrying the running state in VMEM scratch; inside a chunk the
recurrence runs as a fori_loop of VPU-width vector ops over ``block_t``
steps (the classic TPU linear-scan shape, cf. RecurrentGemma's kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import resolve_interpret


def _kernel(a_ref, b_ref, h_all_ref, h_last_ref, h_ref, *, block_t, num_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (bt, bc)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h_new = a[t] * h + b[t]
        h_all_ref[0, t, :] = h_new.astype(h_all_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, block_t, step, h_ref[0])
    h_ref[0] = h

    @pl.when(ti == num_t - 1)
    def _done():
        h_last_ref[0] = h.astype(h_last_ref.dtype)


def linear_recurrence(a, b, *, block_t=128, block_c=512,
                      interpret="auto"):
    """a, b: (B, S, C) -> (h_all (B, S, C), h_last (B, C)).

    Zero initial state (callers fold h0 into b_0 if needed: b_0 += a_0*h0).
    """
    B, S, C = a.shape
    bt = min(block_t, S)
    bc = min(block_c, C)
    assert S % bt == 0 and C % bc == 0, (S, bt, C, bc)
    nt, nc = S // bt, C // bc

    kernel = functools.partial(_kernel, block_t=bt, num_t=nt)
    h_all, h_last = pl.pallas_call(
        kernel,
        grid=(B, nc, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, bt, bc), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bc), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, bc), lambda bi, ci, ti: (bi, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(a, b)
    return h_all, h_last
