"""Fused quantize -> mix -> dequantize -> residual-update Pallas TPU kernel.

The compressed-gossip hot loop (error feedback a la Bagua's low-precision
decentralized algorithm) applied to the flattened (n, D) stacked state:

    for r in range(R):
        buf = x + res                       # error-feedback compensation
        q   = dequant(quant(buf))           # what the wire actually carries
        res = buf - q                       # residual for the next round
        x   = W[r] @ q                      # the gossip mixing itself

An unfused implementation pays one HBM round-trip of the state per stage
per round; here the R-round loop runs entirely in VMEM per D-tile, so HBM
traffic is exactly 2*(x + res) regardless of R — the same fusion the plain
``gossip_matmul`` kernel buys, extended to the quantization stages.  The
quantization math itself is :func:`repro.kernels.ref.quantize_dequantize_ref`
(pure jnp, shared with the oracle and the host path), so the kernel can
never drift from the reference scheme.

Blocking: ``block_d`` must be a multiple of ``group`` so a tile always
holds whole quantization groups — block boundaries then never change the
per-group scales and any legal ``block_d`` is bit-identical to the
reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .interpret import resolve_interpret


def _kernel(w_ref, x_ref, r_ref, o_ref, ro_ref, *, rounds, scheme, group,
            error_feedback):
    w = w_ref[...]                            # (R, n, n), VMEM-resident
    x = x_ref[...].astype(jnp.float32)        # (n, bd)
    res = r_ref[...].astype(jnp.float32)      # (n, bd)

    def body(r, carry):
        e, rs = carry
        buf = e + rs
        deq, err = ref.quantize_dequantize_ref(buf, scheme=scheme,
                                               group=group)
        if error_feedback:  # static: selects the traced graph, not a cond
            rs = err
        e = jax.lax.dot_general(
            w[r].astype(jnp.float32), deq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return e, rs

    out, rs = jax.lax.fori_loop(0, rounds, body, (x, res))
    o_ref[...] = out.astype(o_ref.dtype)
    ro_ref[...] = rs.astype(ro_ref.dtype)


def quantized_gossip_mix(ws, x, res, *, scheme, group=256,
                         error_feedback=True, block_d=1024, interpret="auto"):
    """ws: (R, n, n); x, res: (n, D) -> (mixed x, final residual).

    D must be a multiple of ``group`` (callers pad; zero columns are a
    fixed point of quantize/mix/residual, so padding is exact) and
    ``block_d`` is rounded down to a multiple of ``group``.
    """
    R, n, _ = ws.shape
    N, D = x.shape
    assert N == n and res.shape == (n, D), (x.shape, res.shape, ws.shape)
    assert D % group == 0, (D, group)
    bd = min(block_d, D)
    bd = max(group, (bd // group) * group)
    assert D % bd == 0, (D, bd)
    kernel = functools.partial(_kernel, rounds=R, scheme=scheme, group=group,
                               error_feedback=error_feedback)
    return pl.pallas_call(
        kernel,
        grid=(D // bd,),
        in_specs=[
            pl.BlockSpec((R, n, n), lambda d: (0, 0, 0)),
            pl.BlockSpec((n, bd), lambda d: (0, d)),
            pl.BlockSpec((n, bd), lambda d: (0, d)),
        ],
        out_specs=(
            pl.BlockSpec((n, bd), lambda d: (0, d)),
            pl.BlockSpec((n, bd), lambda d: (0, d)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, D), x.dtype),
            jax.ShapeDtypeStruct((n, D), res.dtype),
        ),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=resolve_interpret(interpret),
    )(ws, x, res)
