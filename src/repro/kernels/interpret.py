"""The one Pallas interpret-mode policy.

A leaf module (imports only jax) so both the raw kernel modules
(:mod:`gossip_matmul`, :mod:`flash_attention`, ...) and the jitted public
wrappers (:mod:`repro.kernels.ops`, which imports the kernels and therefore
cannot be imported BY them) resolve the same policy: ``"auto"`` compiles on
TPU backends and falls back to interpreter mode (Python evaluation of the
kernel body) everywhere else, so the same call sites are correct on CPU CI
and on real accelerators.  Booleans pass through for explicit overrides
(tests, interpreter-mode debugging on TPU).
"""

from __future__ import annotations

import jax


def resolve_interpret(interpret) -> bool:
    """``"auto"`` -> interpret unless the default backend is a TPU;
    booleans pass through.  Resolved at trace time (the flag is a static
    argument), so jitted callers specialize correctly."""
    if interpret == "auto":
        return jax.default_backend() != "tpu"
    return bool(interpret)
