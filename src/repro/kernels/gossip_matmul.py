"""Multi-consensus gossip mixing Pallas TPU kernel.

Computes  X <- W^{(R-1)} ... W^{(1)} W^{(0)} X  for a stack of R gossip
matrices (Algorithm 2's hot loop applied to flattened parameters).  The
matrices are tiny (n <= 64) and live in VMEM for the whole grid step; X
streams through in D-tiles so HBM traffic is exactly 2*n*D elements
regardless of R — this is the fusion the multi-consensus structure buys on
TPU (R separate matmuls would read/write X R times).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import resolve_interpret


def _kernel(w_ref, x_ref, o_ref, *, rounds):
    w = w_ref[...]                # (R, n, n)
    x = x_ref[...].astype(jnp.float32)  # (n, bd)

    def body(r, acc):
        return jax.lax.dot_general(
            w[r].astype(jnp.float32), acc, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    out = jax.lax.fori_loop(0, rounds, body, x)
    o_ref[...] = out.astype(o_ref.dtype)


def gossip_mix(ws, x, *, block_d=1024, interpret="auto"):
    """ws: (R, n, n); x: (n, D) -> (n, D) after R chained mixings."""
    R, n, _ = ws.shape
    N, D = x.shape
    assert N == n
    bd = min(block_d, D)
    assert D % bd == 0, (D, bd)
    kernel = functools.partial(_kernel, rounds=R)
    return pl.pallas_call(
        kernel,
        grid=(D // bd,),
        in_specs=[
            pl.BlockSpec((R, n, n), lambda d: (0, 0, 0)),
            pl.BlockSpec((n, bd), lambda d: (0, d)),
        ],
        out_specs=pl.BlockSpec((n, bd), lambda d: (0, d)),
        out_shape=jax.ShapeDtypeStruct((n, D), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=resolve_interpret(interpret),
    )(ws, x)
