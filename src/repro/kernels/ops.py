"""Jitted public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel path; ``interpret`` controls HOW the
kernel runs and defaults to ``"auto"``: compiled on TPU backends,
interpreter mode (Python evaluation of the kernel body) everywhere else.
So ``use_pallas=True`` means *compiled wherever a backend supports it* —
callers only override ``interpret`` explicitly to force one mode (tests,
interpreter-mode debugging on TPU).  The model code calls through these
wrappers so a single flag flips the whole model between the jnp reference
path (used for dry-run lowering) and the kernel path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .gossip_matmul import gossip_mix as _gossip
from .interpret import resolve_interpret  # noqa: F401  (re-export: the API)
from .linear_recurrence import linear_recurrence as _linrec
from .quantized_gossip import quantized_gossip_mix as _qgossip
from .sparse_gossip import sparse_segment_mix as _sparse_segment


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret", "block_q", "block_k"))
def attention(q, k, v, *, causal=True, window=0, use_pallas=False,
              interpret="auto", block_q=128, block_k=128):
    if use_pallas:
        return _flash(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=resolve_interpret(interpret))
    return ref.attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("window", "use_pallas",
                                             "interpret", "block_k"))
def decode_attention(q, k, v, kpos, pos, *, window=0, use_pallas=False,
                     interpret="auto", block_k=256):
    if use_pallas:
        return _decode(q, k, v, kpos, pos, window=window, block_k=block_k,
                       interpret=resolve_interpret(interpret))
    return ref.decode_attention_ref(q, k, v, kpos, pos, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_t", "block_c"))
def linear_recurrence(a, b, *, use_pallas=False, interpret="auto",
                      block_t=128, block_c=512):
    if use_pallas:
        return _linrec(a, b, block_t=block_t, block_c=block_c,
                       interpret=resolve_interpret(interpret))
    return ref.linear_recurrence_ref(a, b)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_d"))
def gossip_mix(ws, x, *, use_pallas=False, interpret="auto", block_d=1024):
    if use_pallas:
        return _gossip(ws, x, block_d=block_d,
                       interpret=resolve_interpret(interpret))
    return ref.gossip_mix_ref(ws, x)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_e", "block_d"))
def sparse_gossip_mix(x, src, dst, w, seg, slots, *, use_pallas=False,
                      interpret="auto", block_e=512, block_d=512):
    """One edge-list gossip round on an (n, D) state matrix:
    ``z = x + scatter_{dst} w * (x[src] - x[dst])`` (Laplacian form, see
    :mod:`repro.sparse.plan`).

    ``seg``/``slots`` are the compacted receiver segments a
    :meth:`repro.sparse.plan.SparseGossipPlan.tensors` staging provides:
    ``slots`` (S,) holds the distinct receiver ids (padded with an
    out-of-range id, dropped by the scatter) and ``seg[e]`` indexes
    ``dst[e]`` within ``slots``.  Both paths (Pallas segment-sum kernel
    and the ``jax.ops.segment_sum`` reference) share this layout, so they
    agree to float tolerance and padded edges (``w = 0``) are inert.
    """
    xs = jnp.take(x, src, axis=0)
    xd = jnp.take(x, dst, axis=0)
    S = slots.shape[0]
    if use_pallas:
        E, D = xs.shape
        be = min(block_e, max(8, E))
        ep = -E % be
        dp = -D % 128
        pad = lambda a, n_: jnp.pad(a, ((0, n_),) + ((0, 0),) * (a.ndim - 1))
        seg_p, w_p = pad(seg, ep), pad(w, ep)
        xs_p = jnp.pad(xs, ((0, ep), (0, dp)))
        xd_p = jnp.pad(xd, ((0, ep), (0, dp)))
        sp = -S % 8
        delta = _sparse_segment(seg_p, w_p, xs_p, xd_p,
                                num_segments=S + sp, block_e=be,
                                block_d=block_d,
                                interpret=resolve_interpret(interpret))
        delta = delta[:S, :D]
    else:
        delta = ref.sparse_gossip_mix_ref(seg, w, xs, xd, S)
    return x.at[slots].add(delta.astype(x.dtype), mode="drop")


@functools.partial(jax.jit, static_argnames=("scheme", "group",
                                             "error_feedback", "use_pallas",
                                             "interpret", "block_d"))
def quantized_gossip_mix(ws, x, res, *, scheme, group=256,
                         error_feedback=True, use_pallas=False,
                         interpret="auto", block_d=1024):
    """Error-feedback compressed multi-consensus on an (n, D) state matrix:
    per round, quantize (x + res) group-wise, mix the dequantized payload,
    keep the quantization error as the next round's residual.  Returns
    (mixed x, final residual)."""
    if use_pallas:
        return _qgossip(ws, x, res, scheme=scheme, group=group,
                        error_feedback=error_feedback, block_d=block_d,
                        interpret=resolve_interpret(interpret))
    return ref.quantized_gossip_mix_ref(ws, x, res, scheme=scheme,
                                        group=group,
                                        error_feedback=error_feedback)
