"""Jitted public wrappers around the Pallas kernels.

``use_pallas`` selects the kernel path; on this CPU container kernels run
with interpret=True (Python interpretation of the kernel body).  On real
TPU hardware set ``interpret=False``.  The model code calls through these
wrappers so a single flag flips the whole model between the jnp reference
path (used for dry-run lowering) and the kernel path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .gossip_matmul import gossip_mix as _gossip
from .linear_recurrence import linear_recurrence as _linrec


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret", "block_q", "block_k"))
def attention(q, k, v, *, causal=True, window=0, use_pallas=False,
              interpret=True, block_q=128, block_k=128):
    if use_pallas:
        return _flash(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k, interpret=interpret)
    return ref.attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("window", "use_pallas",
                                             "interpret", "block_k"))
def decode_attention(q, k, v, kpos, pos, *, window=0, use_pallas=False,
                     interpret=True, block_k=256):
    if use_pallas:
        return _decode(q, k, v, kpos, pos, window=window, block_k=block_k,
                       interpret=interpret)
    return ref.decode_attention_ref(q, k, v, kpos, pos, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_t", "block_c"))
def linear_recurrence(a, b, *, use_pallas=False, interpret=True,
                      block_t=128, block_c=512):
    if use_pallas:
        return _linrec(a, b, block_t=block_t, block_c=block_c,
                       interpret=interpret)
    return ref.linear_recurrence_ref(a, b)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_d"))
def gossip_mix(ws, x, *, use_pallas=False, interpret=True, block_d=1024):
    if use_pallas:
        return _gossip(ws, x, block_d=block_d, interpret=interpret)
    return ref.gossip_mix_ref(ws, x)
