"""Single-token decode attention Pallas TPU kernel (the serve_step hot spot).

One query token per sequence attends to the (possibly ring-buffered) KV
cache.  Decode is memory-bound — arithmetic intensity ~1 — so the kernel's
job is to stream k/v through VMEM exactly once per step with the masking
(kpos validity, causality vs the current position, optional sliding window)
fused in, instead of materializing masked score tensors in HBM.

Grid: (batch, kv_heads, num_k_blocks); the k-block axis is innermost /
sequential, carrying the online-softmax state for all G = H/KV query heads
of the kv head in VMEM scratch.  BlockSpec streams (block_k, hd) cache
tiles; the (G, hd) query tile stays resident.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, kpos_ref, pos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, window, num_k_blocks):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :]                     # (G, hd)
    k = k_ref[0, :, 0, :]                     # (bk, hd)
    v = v_ref[0, :, 0, :]
    kpos = kpos_ref[...]                      # (bk,)
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (kpos >= 0) & (kpos <= pos)
    if window:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kpos, pos, *, window=0, block_k=256,
                     interpret="auto"):
    """q: (B, 1, J, G, hd); k, v: (B, C, J, hd); kpos: (C,) int32 absolute
    positions (-1 = empty slot); pos: scalar int32 current position.
    Returns (B, 1, J*G, hd) — matches repro.models.attention.decode_attend.
    """
    B, _, J, G, hd = q.shape
    C = k.shape[1]
    bk = min(block_k, C)
    assert C % bk == 0, (C, bk)
    nk = C // bk
    scale = 1.0 / math.sqrt(hd)
    q2 = q.reshape(B, J, G, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, J, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, j, i: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, j, i: (b, i, j, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, j, i: (b, i, j, 0)),
            pl.BlockSpec((bk,), lambda b, j, i: (i,)),
            pl.BlockSpec((1,), lambda b, j, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, j, i: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, J, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(q2, k, v, kpos, pos_arr)
    return out.reshape(B, 1, J * G, hd)
