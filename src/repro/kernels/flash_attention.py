"""Flash attention Pallas TPU kernel (blockwise online softmax, GQA,
optional causal + sliding-window masking).

Grid: (batch, q_heads, num_q_blocks, num_k_blocks); the k-block axis is the
innermost (sequential on TPU), carrying the online-softmax state (m, l, acc)
in VMEM scratch.  BlockSpecs tile q/k/v into (block_q|block_k, head_dim)
VMEM tiles; head_dim should be a multiple of 128 on real hardware for MXU
alignment (the kernel itself is shape-agnostic).

TARGET: TPU.  Validated on CPU via interpret=True (see tests/test_kernels.py);
the model's jnp reference path (ref.py) is used for dry-run lowering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, block_q, block_k, num_k_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]                      # (bq, hd)
    k = k_ref[0, :, 0, :]                      # (bk, hd)
    v = v_ref[0, :, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret="auto"):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with KV dividing H.
    Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(q, k, v)
