"""Pure-jnp oracles for every Pallas kernel (the dry-run lowering path and
the allclose targets in tests/test_kernels.py)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqjgh,bkjh->bjgqk", qg, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bjgqk,bkjh->bqjgh", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


def decode_attention_ref(q, k, v, kpos, pos, *, window=0):
    """q: (B,1,J,G,hd); k,v: (B,C,J,hd); kpos: (C,); pos: scalar."""
    hd = q.shape[-1]
    s = jnp.einsum("bqjgh,bkjh->bjgqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    mask = (kpos >= 0) & (kpos <= pos)
    if window:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bjgqk,bkjh->bqjgh", p.astype(v.dtype), v)
    B, _, J, G, _ = q.shape
    return o.reshape(B, 1, J * G, hd)


def linear_recurrence_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t, h_{-1} = 0.  a, b: (B, S, C)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a32 = a.astype(jnp.float32).swapaxes(0, 1)
    b32 = b.astype(jnp.float32).swapaxes(0, 1)
    h0 = jnp.zeros(a.shape[::2], jnp.float32)  # (B, C)
    h_last, h_all = jax.lax.scan(step, h0, (a32, b32))
    return h_all.swapaxes(0, 1), h_last


def gossip_mix_ref(ws, x):
    """ws: (R, n, n); x: (n, D)."""
    out = x.astype(jnp.float32)
    for r in range(ws.shape[0]):
        out = ws[r].astype(jnp.float32) @ out
    return out.astype(x.dtype)


def sparse_gossip_mix_ref(seg, w, xs, xd, num_segments):
    """Segment-sum of weighted edge differences, the sparse-gossip oracle.

    ``delta[s] = sum_{e: seg[e] == s} w[e] * (xs[e] - xd[e])`` — the
    per-receiver update of one edge-list gossip round in Laplacian form
    (see :mod:`repro.sparse.plan`).  seg: (E,) int32; w: (E,);
    xs, xd: (E, D) gathered endpoint states.  Padded edges carry w = 0 and
    contribute nothing.  Returns (num_segments, D) float32.
    """
    contrib = w[:, None].astype(jnp.float32) * (
        xs.astype(jnp.float32) - xd.astype(jnp.float32))
    return jax.ops.segment_sum(contrib, seg, num_segments=num_segments)


def quantize_dequantize_ref(buf, *, scheme, group=256):
    """Group-wise quantize -> dequantize of an (n, D) f32 matrix
    (D % group == 0); returns (dequantized, error = buf - dequantized).

    ``sign``: 1 bit/entry + one f32 scale per (node, group), scale =
    mean|buf| over the group (the 1-bit scheme of Bernstein et al. /
    Bagua's low-precision decentralized path).  ``int8``: symmetric
    absmax/127 per (node, group).  Pure jnp, so the SAME function is the
    test oracle, the unfused host path, and the Pallas kernel body — the
    quantization math exists exactly once.
    """
    n, D = buf.shape
    g = buf.reshape(n, D // group, group)
    if scheme == "sign":
        scale = jnp.mean(jnp.abs(g), axis=-1, keepdims=True)
        deq = jnp.sign(g) * scale
    elif scheme == "int8":
        scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(g / safe), -127.0, 127.0)
        deq = q * scale
    else:
        raise ValueError(f"unknown compression scheme {scheme!r} "
                         "(quantizing schemes: 'sign', 'int8')")
    deq = deq.reshape(n, D)
    return deq, buf - deq


def quantized_gossip_mix_ref(ws, x, res, *, scheme, group=256,
                             error_feedback=True):
    """Error-feedback compressed multi-consensus, the oracle for the fused
    Pallas kernel.  Per round r: buf = x + res; q = deq(quant(buf));
    res <- buf - q (when ``error_feedback``); x <- ws[r] @ q.

    ws: (R, n, n); x, res: (n, D) with D % group == 0.
    Returns (mixed x, final residual)."""
    out = x.astype(jnp.float32)
    rs = res.astype(jnp.float32)
    for r in range(ws.shape[0]):
        buf = out + rs
        deq, err = quantize_dequantize_ref(buf, scheme=scheme, group=group)
        if error_feedback:
            rs = err
        out = ws[r].astype(jnp.float32) @ deq
    return out.astype(x.dtype), rs.astype(res.dtype)
