"""Production mesh definitions.

Functions, not module-level constants: importing this module never touches
jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so ``jax.make_mesh`` can build these meshes on the CPU container.

Production target: TPU v5e, 16x16 = 256 chips per pod; 2 pods = 512 chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_hierarchical_mesh(nodes: int = 4, fsdp: int = 4, model: int = 16):
    """Beyond-paper mesh: same 256 chips as the single-pod production mesh,
    but the decentralized node axis is only `nodes` wide and each node's
    model copy is sharded over fsdp*model ways — 4x less parameter/state
    HBM per device at the cost of wider-activation collectives."""
    axes = ("node", "fsdp", "model")
    auto = (jax.sharding.AxisType.Auto,) * 3
    return jax.make_mesh((nodes, fsdp, model), axes, axis_types=auto)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=auto)


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
