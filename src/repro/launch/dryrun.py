import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh, record memory/cost analysis and the collective
schedule (deliverable e; feeds EXPERIMENTS.md §Dry-run / §Roofline).

Cost accounting: XLA's HloCostAnalysis counts while-loop (lax.scan) bodies
ONCE, so the scan-over-layers lowering under-reports FLOPs/bytes/collective
volume.  The dry-run therefore does two things per combination:

  1. compiles the FULL config with scan-over-layers — this is the artifact
     that proves the (arch x shape x mesh) lowers, and its memory_analysis
     is the realistic per-device footprint;
  2. compiles two small UNROLLED probes (1 and 2 pattern-units, every scan
     replaced by a Python loop) and extrapolates cost linearly in the unit
     count: cost(L) = c1 + (c2 - c1) * (units - 1) [+ pro-rated remainder].
     Extrapolation is exact because pattern units are identical subgraphs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import sharding as shd
from repro.dist import steps as dsteps
from repro.launch import mesh as meshlib
from repro.models import build, model as modellib

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def config_for_shape(cfg, shape_name: str):
    """Shape-specific config adjustments: long_500k requires sub-quadratic
    attention -> enable the sliding-window variant (4096) on archs whose
    attention is otherwise full-causal.  SSM archs need nothing."""
    if shape_name == "long_500k" and cfg.num_heads and not cfg.window:
        return dataclasses.replace(cfg, window=4096)
    return cfg


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    pat = re.compile(
        r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(COLLECTIVES) + r")\(")
    tuple_pat = re.compile(
        r"=\s+\(([^)]+)\)\s+(" + "|".join(COLLECTIVES) + r")\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if m:
            dtype, dims, op = m.groups()
            nbytes = _DTYPE_BYTES.get(dtype, 4)
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            out[op] += size * nbytes
            counts[op] += 1
            continue
        m = tuple_pat.search(line)
        if m:
            parts, op = m.groups()
            for piece in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", parts):
                dtype, dims = piece.groups()
                nbytes = _DTYPE_BYTES.get(dtype, 4)
                size = 1
                for d in dims.split(","):
                    if d:
                        size *= int(d)
                out[op] += size * nbytes
            counts[op] += 1
    return {"per_op": out, "counts": counts, "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Lowering (shared by the full compile and the cost probes)
# ---------------------------------------------------------------------------

def _lower(cfg, shape, mesh, *, R: int, gamma: float, unroll_step: bool,
           train_kwargs: dict | None = None):
    """Lower the appropriate step for ``shape.kind`` under ``mesh``."""
    model = build(cfg)
    dtype = jnp.dtype(cfg.dtype)
    tkw = dict(train_kwargs or {})
    if shape.kind == "train":
        n = shd.n_nodes(mesh)
        b = max(1, shape.global_batch // (n * R))
        tmpl = modellib.train_batch_template(cfg, b, shape.seq_len, dtype)
        batch = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, R) + s.shape, s.dtype), tmpl)
        init_state, _, train_step = dsteps.make_train_step(
            model, cfg, gamma=gamma, R=R, unroll=unroll_step, **tkw)
        state = jax.eval_shape(lambda: init_state(jax.random.key(0), n, dtype))
        if tkw.get("gossip_impl") == "sun":
            weights = jax.ShapeDtypeStruct((2 * R, n), jnp.float32)
        else:
            weights = jax.ShapeDtypeStruct((2 * R, n, n), jnp.float32)
        state_specs = dsteps.TrainState(
            x=shd.param_specs(state.x, cfg, mesh, stacked_nodes=True),
            h=shd.param_specs(state.h, cfg, mesh, stacked_nodes=True),
            g_prev=shd.param_specs(state.g_prev, cfg, mesh, stacked_nodes=True),
            step=P())
        bspecs = shd.batch_specs(batch, mesh, stacked_nodes=True)
        return jax.jit(train_step, in_shardings=(state_specs, bspecs, P()),
                       out_shardings=(state_specs, {"loss": P()})).lower(
            state, batch, weights)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0), dtype))
    pspecs = shd.param_specs(params, cfg, mesh)
    is_audio = cfg.arch_type == "audio"
    if shape.kind == "prefill":
        B = shape.global_batch
        tmpl = modellib.train_batch_template(cfg, B, shape.seq_len, dtype)
        cache = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len, dtype))
        cspecs = shd.param_specs(cache, cfg, mesh, audio_cache=is_audio)
        bspecs = shd.batch_specs(tmpl, mesh, stacked_nodes=False)
        step = dsteps.make_prefill_step(model, cfg)
        return jax.jit(step, in_shardings=(pspecs, bspecs, cspecs)).lower(
            params, tmpl, cache)
    B = shape.global_batch
    token, cache, pos = modellib.decode_templates(cfg, B, shape.seq_len, dtype)
    cspecs = shd.param_specs(cache, cfg, mesh, audio_cache=is_audio)
    tok_spec = shd.batch_specs({"t": token}, mesh, stacked_nodes=False)["t"]
    step = dsteps.make_serve_step(model, cfg)
    return jax.jit(step, in_shardings=(pspecs, tok_spec, cspecs, P())).lower(
        params, token, cache, pos)


def _probe_cfg(cfg, k_units: int):
    pat = len(cfg.pattern)
    repl = dict(num_layers=k_units * pat, unroll=True,
                q_chunk=10_000_000, scan_chunk=10_000_000)
    if cfg.encoder_layers:
        repl["encoder_layers"] = k_units
    return dataclasses.replace(cfg, **repl)


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on newer jax, a one-element
    list of dicts on 0.4.x — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _costs_of(compiled) -> dict:
    cost = _cost_dict(compiled)
    coll = parse_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              R: int = 2, gamma: float = 1e-3, verbose: bool = True,
              probe: bool = True, cfg_transform=None,
              train_kwargs: dict | None = None, mesh_builder=None) -> dict:
    cfg = config_for_shape(configs.get(arch), shape_name)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = configs.INPUT_SHAPES[shape_name]
    mesh = (mesh_builder() if mesh_builder is not None
            else meshlib.make_production_mesh(multi_pod=multi_pod))
    t0 = time.time()

    with jax.set_mesh(mesh):
        compiled = _lower(cfg, shape, mesh, R=R, gamma=gamma,
                          unroll_step=False, train_kwargs=train_kwargs).compile()
        probe_costs = None
        if probe:
            c1 = _costs_of(_lower(_probe_cfg(cfg, 1), shape, mesh, R=R,
                                  gamma=gamma, unroll_step=True,
                                  train_kwargs=train_kwargs).compile())
            c2 = _costs_of(_lower(_probe_cfg(cfg, 2), shape, mesh, R=R,
                                  gamma=gamma, unroll_step=True,
                                  train_kwargs=train_kwargs).compile())
            probe_costs = (c1, c2)

    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll_scan = parse_collective_bytes(compiled.as_text())

    units, rem = cfg.units_and_rem
    if probe_costs:
        c1, c2 = probe_costs
        scale = (units - 1) + rem / len(cfg.pattern)

        def extrap(f1, f2):
            return f1 + (f2 - f1) * scale

        flops = extrap(c1["flops"], c2["flops"])
        nbytes = extrap(c1["bytes"], c2["bytes"])
        coll_total = extrap(c1["coll"]["total_bytes"], c2["coll"]["total_bytes"])
        coll_per_op = {k: extrap(c1["coll"]["per_op"][k], c2["coll"]["per_op"][k])
                       for k in c1["coll"]["per_op"]}
        collectives = {"per_op": coll_per_op, "total_bytes": coll_total,
                       "counts_1unit": c1["coll"]["counts"]}
    else:
        flops = float(cost.get("flops", -1))
        nbytes = float(cost.get("bytes accessed", -1))
        collectives = coll_scan

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": ("x".join(str(mesh.shape[a]) for a in mesh.axis_names)
                 if mesh_builder is not None
                 else ("2x16x16" if multi_pod else "16x16")),
        "devices": int(mesh.size),
        "compile_seconds": round(t1 - t0, 1),
        "flops": flops,
        "bytes_accessed": nbytes,
        "flops_scanbody": float(cost.get("flops", -1)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "output_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "collectives": collectives,
        "collectives_scanbody": coll_scan,
    }
    if verbose:
        print(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(configs.INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--R", type=int, default=2)
    args = ap.parse_args()

    archs = [a for a in configs.names()] if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(configs.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
            try:
                res = lower_one(arch, shape, multi_pod=args.multi_pod,
                                R=args.R, verbose=False,
                                probe=not args.no_probe)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=2)
                print(f"OK   {tag}: compile={res['compile_seconds']}s "
                      f"flops={res['flops']:.3e} "
                      f"coll={res['collectives']['total_bytes']:.3e}B",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, str(e)[:200]))
                print(f"FAIL {tag}: {str(e)[:200]}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: "
                         + "; ".join(t for t, _ in failures))
    print("all dry-runs compiled")


if __name__ == "__main__":
    main()
