"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun), derives
the three roofline terms per (arch x shape x mesh) and emits a markdown
table plus per-pair bottleneck classification.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis on the SPMD-partitioned module is per-device; verified by
halving per-device flops when doubling the pod count.)

MODEL_FLOPS uses 6*N*D for training (2ND fwd + 4ND bwd) and 2*N*D for
inference, with N_active for MoE.  The utilization column
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import build

V5E_HBM_BYTES = 16e9


def _param_counts(cfg):
    """(total, active) parameter counts via eval_shape (no allocation)."""
    model = build(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0), jnp.bfloat16))
    total = sum(int(x.size) for x in jax.tree.leaves(params))
    active = total
    if cfg.num_experts and cfg.experts_per_token:
        # each token runs k of E experts
        def expert_size(tree):
            out = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                names = [str(getattr(k, "key", k)) for k in path]
                if "moe" in names and names[-1] in ("wi", "wg", "wo"):
                    out += int(leaf.size)
            return out
        es = expert_size(params)
        active = total - es + es * cfg.experts_per_token / cfg.num_experts
    return total, active


def model_flops_per_device(cfg, shape, devices: int, train_nodes: int,
                           R: int = 2) -> float:
    total, active = _param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens / devices
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch / devices


def analyse(record: dict, R: int = 2) -> dict:
    cfg = configs.get(record["arch"])
    shape = configs.INPUT_SHAPES[record["shape"]]
    devices = record["devices"]
    n_nodes = 32 if record["mesh"] == "2x16x16" else 16

    t_compute = record["flops"] / PEAK_FLOPS_BF16
    t_memory = record["bytes_accessed"] / HBM_BW
    t_coll = record["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, shape, devices, n_nodes, R)
    useful = mf / record["flops"] if record["flops"] > 0 else 0.0
    peak = record["memory"]["peak_bytes"]
    return {
        **record,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": useful,
        "fits_hbm": peak <= V5E_HBM_BYTES,
        "hbm_frac": peak / V5E_HBM_BYTES,
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful FLOPs | HBM frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                 f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
                 f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
                 f"| {r['useful_flops_ratio']:.2f} "
                 f"| {r['hbm_frac']:.2f}{'' if r['fits_hbm'] else ' ⚠OVER'} |\n")
    return hdr + body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(path))
        if args.mesh != "all" and rec["mesh"] != args.mesh:
            continue
        rows.append(analyse(rec))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    table = markdown_table(rows)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
    # summary of the three most interesting pairs
    if rows:
        coll_bound = max(rows, key=lambda r: r["t_collective_s"]
                         / max(sum((r["t_compute_s"], r["t_memory_s"],
                                    r["t_collective_s"])), 1e-30))
        worst_useful = min((r for r in rows if r["shape"] == "train_4k"),
                           key=lambda r: r["useful_flops_ratio"], default=None)
        print(f"\nmost collective-bound: {coll_bound['arch']}/{coll_bound['shape']}")
        if worst_useful:
            print(f"worst useful-FLOPs (train): {worst_useful['arch']} "
                  f"({worst_useful['useful_flops_ratio']:.2f})")


if __name__ == "__main__":
    main()
