import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Performance hillclimb (deliverable g §Perf): lower baseline and optimized
variants of the three chosen (arch x shape) pairs, compare roofline terms.

Pairs (chosen from the baseline roofline table):
  1. nemotron-4-340b x train_4k  — most collective-bound; also most
     representative of the paper's technique (multi-consensus gossip over
     340B params dominates).
  2. granite-moe-3b-a800m x prefill_32k — worst roofline fraction: the MoE
     einsum dispatch at 1M tokens explodes the memory term.
  3. internvl2-1b x prefill_32k — collective-bound through the replicated
     non-divisible-vocab unembed of the full 32k positions.

Variants are opt-in config/step flags (defaults = paper-faithful baseline):
  sun-gossip     gossip_impl='sun'  — structured all-reduce gossip, exact
                 for sun-shaped W (O(2V) wire vs O(nV) gather)
  moe-group      cfg.moe_seq_group=4096 — per-group MoE dispatch
  last-unembed   cfg.prefill_last_only=True — unembed 1 position at prefill
  bf16-state     aux_dtype=bf16 — MC-DSGT tracker/accumulator in bf16

Usage:
    PYTHONPATH=src python -m repro.launch.hillclimb [--pair N] [--out FILE]
"""

import argparse
import dataclasses
import json
import time

import jax.numpy as jnp

from repro.launch.dryrun import lower_one
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_hierarchical_mesh


def terms(rec: dict) -> dict:
    return {
        "compute_s": rec["flops"] / PEAK_FLOPS_BF16,
        "memory_s": rec["bytes_accessed"] / HBM_BW,
        "collective_s": rec["collectives"]["total_bytes"] / ICI_BW,
        "peak_hbm_frac": rec["memory"]["peak_bytes"] / 16e9,
    }


PAIRS = {
    "nemotron-train": dict(
        arch="nemotron-4-340b", shape="train_4k",
        variants={
            "baseline": {},
            "sun-gossip": {"train_kwargs": {"gossip_impl": "sun",
                                            "sun_delta": 1.0}},
            "bf16-state": {"train_kwargs": {"aux_dtype": jnp.bfloat16}},
            "sun+bf16": {"train_kwargs": {"gossip_impl": "sun",
                                          "sun_delta": 1.0,
                                          "aux_dtype": jnp.bfloat16}},
            "sun+bf16+hier4x64": {"train_kwargs": {"gossip_impl": "sun",
                                                   "sun_delta": 1.0,
                                                   "aux_dtype": jnp.bfloat16},
                                  "mesh_builder": lambda: make_hierarchical_mesh(4, 4, 16)},
        }),
    "granite-prefill": dict(
        arch="granite-moe-3b-a800m", shape="prefill_32k",
        variants={
            "baseline": {},
            "moe-group4k": {"cfg_transform": lambda c: dataclasses.replace(
                c, moe_seq_group=4096)},
            "moe-group4k+last": {"cfg_transform": lambda c: dataclasses.replace(
                c, moe_seq_group=4096, prefill_last_only=True)},
            "grp+last+replattn": {"cfg_transform": lambda c: dataclasses.replace(
                c, moe_seq_group=4096, prefill_last_only=True,
                attn_shard_fallback="replicate")},
            "grp+last+ra+pad48": {"cfg_transform": lambda c: dataclasses.replace(
                c, moe_seq_group=4096, prefill_last_only=True,
                attn_shard_fallback="replicate", moe_pad_experts=48)},
        }),
    "internvl2-prefill": dict(
        arch="internvl2-1b", shape="prefill_32k",
        variants={
            "baseline": {},
            "last-unembed": {"cfg_transform": lambda c: dataclasses.replace(
                c, prefill_last_only=True)},
            "last+repl-attn": {"cfg_transform": lambda c: dataclasses.replace(
                c, prefill_last_only=True, attn_shard_fallback="replicate")},
        }),
}


def run_pair(name: str, spec: dict, out: dict):
    print(f"=== {name}: {spec['arch']} x {spec['shape']} ===", flush=True)
    for vname, kw in spec["variants"].items():
        t0 = time.time()
        rec = lower_one(spec["arch"], spec["shape"], verbose=False,
                        cfg_transform=kw.get("cfg_transform"),
                        train_kwargs=kw.get("train_kwargs"),
                        mesh_builder=kw.get("mesh_builder"))
        tt = terms(rec)
        out.setdefault(name, {})[vname] = {**tt,
                                           "flops": rec["flops"],
                                           "bytes": rec["bytes_accessed"],
                                           "coll_bytes": rec["collectives"]["total_bytes"],
                                           "coll_per_op": rec["collectives"].get("per_op"),
                                           "compile_s": rec["compile_seconds"]}
        print(f"  {vname:18s} compute {tt['compute_s']:.3e}s  "
              f"memory {tt['memory_s']:.3e}s  "
              f"collective {tt['collective_s']:.3e}s  "
              f"hbm {tt['peak_hbm_frac']:.2f}  "
              f"({time.time() - t0:.0f}s to lower)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=list(PAIRS) + [None])
    ap.add_argument("--out", default="experiments/hillclimb.json")
    args = ap.parse_args()
    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    pairs = {args.pair: PAIRS[args.pair]} if args.pair else PAIRS
    for name, spec in pairs.items():
        run_pair(name, spec, results)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
