"""Personalized fleet serving CLI — a thin argv -> spec translator.

Like :mod:`repro.launch.train`, every flag maps to one field of
:class:`repro.exp.ExperimentSpec` (see ``FLAG_TO_FIELD``) and the run
itself is ``repro.exp.run(spec)``: train the fleet (or ``--restore`` a
checkpointed one), then serve ``--requests`` synthetic routed requests
against it with continuous batching (:mod:`repro.serve`).  There is no
serving code here — dtype policy comes from ``--dtype`` (ServeSpec) and
decode attention follows the model's kernel policy layer
(:mod:`repro.kernels.ops` with ``interpret="auto"``), not per-call jits.

Config files round-trip exactly as in train: ``--config PATH`` loads a
spec JSON as the baseline, explicit flags override it, and
``--dump-config`` prints the fully-resolved spec JSON and exits.

Example — train a 16-node personalized fleet and serve 64 requests:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --preset reduced --nodes 16 --steps 30 --algo personalized \
        --requests 64 --batch 8 --max-new 16 --routing user-affinity
"""

from __future__ import annotations

import argparse

from repro import exp

# flag dest -> dotted ExperimentSpec field (same contract as launch.train:
# argparse.SUPPRESS keeps unset flags out of the namespace, so the
# baseline — dataclass defaults or --config — survives untouched).
FLAG_TO_FIELD = {
    "arch": "model.arch",
    "preset": "model.preset",
    "steps": "run.steps",
    "nodes": "run.nodes",
    "topology": "topology.kind",
    "radius": "topology.radius",
    "algo": "algorithm.name",
    "gamma": "algorithm.gamma",
    "tau": "algorithm.tau",
    "gossip_impl": "run.gossip_impl",
    "link_drop": "channel.link_drop",
    "hetero_alpha": "data.hetero_alpha",
    "batch": "data.batch",
    "seq": "data.seq",
    "active_vocab": "data.active_vocab",
    "checkpoint": "run.checkpoint",
    "restore": "run.restore",
    "log_every": "run.log_every",
    "seed": "run.seed",
    "metrics": "obs.metrics",
    "requests": "serve.requests",
    "serve_batch": "serve.batch",
    "max_new": "serve.max_new",
    "prompt_len": "serve.prompt_len",
    "fleet": "serve.fleet",
    "routing": "serve.routing",
    "dtype": "serve.dtype",
    "serve_seed": "serve.seed",
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(argument_default=argparse.SUPPRESS)
    ap.add_argument("--config", metavar="PATH",
                    help="baseline spec JSON (a spec or a manifest); "
                         "explicit flags override it")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the fully-resolved spec JSON and exit")
    # -- training side (the fleet being served) ----------------------------
    ap.add_argument("--arch", help="registered LM architecture")
    ap.add_argument("--preset", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int)
    ap.add_argument("--nodes", type=int,
                    help="fleet size: one personalized model per node")
    ap.add_argument("--topology", choices=list(exp.TOPOLOGIES))
    ap.add_argument("--radius", type=float,
                    help="unit-disk range for the mobility topologies")
    ap.add_argument("--algo", choices=list(exp.ALGORITHMS),
                    help="'personalized' trains genuinely distinct per-node "
                         "models (loss-proximity neighbor averaging)")
    ap.add_argument("--gamma", type=float)
    ap.add_argument("--tau", type=float,
                    help="personalized rule: loss-proximity temperature "
                         "(higher = sharper clustering)")
    ap.add_argument("--gossip-impl", choices=list(exp.GOSSIP_IMPLS))
    ap.add_argument("--link-drop", type=float,
                    help="per-round per-link drop probability (repro.sim)")
    ap.add_argument("--hetero-alpha", type=float,
                    help="Dirichlet(alpha) non-iid data across nodes — what "
                         "makes per-node personalization worth serving")
    ap.add_argument("--batch", type=int, help="training batch per node")
    ap.add_argument("--seq", type=int)
    ap.add_argument("--active-vocab", type=int)
    ap.add_argument("--checkpoint")
    ap.add_argument("--restore",
                    help="serve a previously trained fleet: restore the "
                         "checkpoint, run 0 further steps with --steps 0")
    ap.add_argument("--log-every", type=int)
    ap.add_argument("--seed", type=int)
    ap.add_argument("--metrics", metavar="PATH",
                    help="repro.obs JSONL event log — includes one "
                         "serve_request event per completion and a final "
                         "serve_summary")
    # -- serving side (ServeSpec) ------------------------------------------
    ap.add_argument("--requests", type=int,
                    help="synthetic requests to serve after training "
                         "(0 disables the serve phase)")
    ap.add_argument("--serve-batch", type=int, dest="serve_batch",
                    help="continuous-batching decode slots")
    ap.add_argument("--max-new", type=int, dest="max_new",
                    help="tokens generated per request")
    ap.add_argument("--prompt-len", type=int, dest="prompt_len")
    ap.add_argument("--fleet", type=int,
                    help="serve only the first N node models "
                         "(0 = the whole fleet)")
    ap.add_argument("--routing", choices=sorted(exp.ROUTING_POLICIES),
                    help="user-affinity pins each user to one node's "
                         "personalization; round-robin cycles the fleet")
    ap.add_argument("--dtype", choices=sorted(exp.SERVE_DTYPES),
                    help="serve-time parameter/KV-cache dtype")
    ap.add_argument("--serve-seed", type=int, dest="serve_seed",
                    help="traffic synthesis seed (users + prompts)")
    ap.add_argument("--quiet", action="store_true", default=False)
    return ap


def spec_from_args(args: argparse.Namespace) -> exp.ExperimentSpec:
    spec = exp.load(args.config) if getattr(args, "config", None) \
        else exp.ExperimentSpec()
    overrides = {FLAG_TO_FIELD[dest]: value
                 for dest, value in vars(args).items()
                 if dest in FLAG_TO_FIELD}
    # serving is the point of this CLI: default the phase ON so a bare
    # invocation serves, while --config files keep their own value
    if "serve.requests" not in overrides and not getattr(args, "config",
                                                         None):
        overrides["serve.requests"] = 64
    return exp.with_overrides(spec, overrides)


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args)
    if getattr(args, "dump_config", False):
        print(exp.to_json(spec, elide_defaults=False))
        return spec
    return exp.run(spec, quiet=args.quiet).serve


if __name__ == "__main__":
    main()
