"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --preset reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import build, materialize_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.key(args.seed), jnp.float32)

    max_len = args.prompt_len + args.gen
    batch = materialize_batch(cfg, args.batch, args.prompt_len,
                              jax.random.key(args.seed + 1), jnp.float32)
    cache = model.init_cache(args.batch, max_len, jnp.float32)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    P = (cfg.frontend_tokens if cfg.arch_type == "vlm" else 0)
    pos0 = batch["tokens"].shape[1] + P
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s "
          f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode:.3f}s "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())
    return gen


if __name__ == "__main__":
    main()
