"""End-to-end decentralized training CLI.

Runs any :mod:`repro.core.engine` update rule (MC-DSGT / DSGT / DSGD / D² /
local_sgd / gt_local) over a time-varying topology schedule on any
registered architecture (reduced or full), with checkpointing and loss /
consensus logging.  The staging, window gather, restore-or-warm and loop
all come from the unified :mod:`repro.core.driver` — this file only parses
flags and binds the pieces.  On the CPU container this runs the reduced
configs; on a real TPU pod, pass --mesh production to shard over the
16x16 mesh.

Example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset reduced --steps 50 --nodes 8 --beta 0.875 --algo mc_dsgt --R 2

The paper's federated scenario (one rule, zero runtime edits):
    PYTHONPATH=src python -m repro.launch.train --algo local_sgd \
        --topology federated --hetero-alpha 0.1 --gossip-impl auto

The wireless scenario (repro.sim): moving nodes, lossy channel, telemetry:
    PYTHONPATH=src python -m repro.launch.train --topology geometric-mobility \
        --nodes 16 --link-drop 0.2 --gossip-impl auto --telemetry telem.json
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import driver, engine, gossip, topology as topo
from repro.data import token_stream_for
from repro.dist import steps as dsteps
from repro.models import build
from repro.sim import channel as sim_channel, faults as sim_faults, \
    mobility as sim_mobility, telemetry as sim_telemetry


def make_weight_schedule(kind: str, n: int, beta: float, *,
                         horizon: int | None = None, seed: int = 0,
                         er_p: float = 0.5,
                         radius: float = 0.45) -> gossip.WeightSchedule:
    """Build the weight schedule for one named topology scenario.

    ``horizon`` (total gossip rounds the run will consume) is required by
    the non-periodic schedules (``resampled-matching`` and the mobility
    models); ``er_p`` is the Erdős–Rényi edge probability; ``radius`` the
    unit-disk communication range of the mobility models."""
    if kind == "sun":
        return gossip.theorem3_weight_schedule(n, beta)
    if kind == "one-peer-exp":
        return gossip.schedule_from_topology(topo.one_peer_exponential_schedule(n))
    if kind == "ring":
        return gossip.schedule_from_topology(topo.StaticSchedule(topo.ring_graph(n)))
    if kind == "static-exp":
        return gossip.schedule_from_topology(
            topo.StaticSchedule(topo.static_exponential_graph(n)))
    if kind == "federated":
        return gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    if kind == "random-matching":
        return gossip.schedule_from_topology(topo.random_matching_schedule(n))
    if kind == "resampled-matching":
        return gossip.schedule_from_topology(
            topo.resampled_matching_schedule(n, seed=seed), horizon=horizon)
    if kind == "erdos-renyi":
        return gossip.schedule_from_topology(
            topo.erdos_renyi_schedule(n, er_p, seed=seed))
    if kind == "geometric-mobility":
        return gossip.schedule_from_topology(
            sim_mobility.random_geometric_schedule(n, radius, seed=seed),
            horizon=horizon)
    if kind == "waypoint-mobility":
        return gossip.schedule_from_topology(
            sim_mobility.random_waypoint_schedule(n, radius, seed=seed),
            horizon=horizon)
    if kind == "complete":
        return gossip.WeightSchedule((np.ones((n, n)) / n,))
    raise ValueError(kind)

TOPOLOGIES = ["sun", "ring", "one-peer-exp", "static-exp", "federated",
              "complete", "random-matching", "resampled-matching",
              "erdos-renyi", "geometric-mobility", "waypoint-mobility"]


def consensus_error(x) -> float:
    return sim_telemetry.consensus_distance(x)


def make_fault_models(args) -> list:
    """Channel/fault models from the CLI degradation flags (empty when the
    channel is ideal).  Seeds are offset per stream so --seed moves every
    stream together without correlating them."""
    models = []
    if args.link_drop > 0:
        models.append(sim_channel.BernoulliDropChannel(
            args.link_drop, seed=args.seed + 101))
    if args.burst_loss > 0:
        models.append(sim_channel.GilbertElliottChannel(
            args.burst_loss, seed=args.seed + 202))
    if args.churn > 0:
        models.append(sim_faults.NodeChurn(args.churn, seed=args.seed + 303))
    if args.straggler > 0:
        models.append(sim_faults.StragglerInjection(
            args.straggler, seed=args.seed + 404))
    return models


LOCAL_OPTS = {"sgd": None, "momentum": optim.momentum, "adam": optim.adam}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.75)
    ap.add_argument("--topology", default="sun", choices=TOPOLOGIES)
    ap.add_argument("--algo", default="mc_dsgt",
                    choices=list(engine.ALGORITHMS))
    ap.add_argument("--gossip-impl", default="dense",
                    choices=["dense", "pallas", "auto"],
                    help="multi-consensus path: GSPMD einsum (dense), the "
                         "fused Pallas gossip_mix kernel (interpret-mode "
                         "fallback on CPU), or per-round structured dispatch "
                         "from the gossip plan (auto: sun / matching / "
                         "complete lowerings, dense fallback)")
    ap.add_argument("--local-opt", default="sgd",
                    choices=sorted(LOCAL_OPTS),
                    help="local-optimizer transform applied to the descent "
                         "direction (repro.optim; sgd = the paper-pure "
                         "update, no transform)")
    ap.add_argument("--er-p", type=float, default=0.5,
                    help="edge probability for --topology erdos-renyi")
    ap.add_argument("--radius", type=float, default=0.45,
                    help="unit-disk communication range for the mobility "
                         "topologies (geometric-mobility, waypoint-mobility)")
    ap.add_argument("--link-drop", type=float, default=0.0,
                    help="iid per-round per-link Bernoulli drop probability "
                         "(repro.sim channel degradation)")
    ap.add_argument("--burst-loss", type=float, default=0.0,
                    help="Gilbert-Elliott bursty loss: per-round good->bad "
                         "transition probability (bad links drop their "
                         "round; recovery 0.25/round)")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="per-round node failure probability (a down node "
                         "loses all links; recovery 0.3/round)")
    ap.add_argument("--straggler", type=float, default=0.0,
                    help="per-round per-node straggler probability (a "
                         "straggler's links miss the round deadline and "
                         "are dropped)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the repro.sim mixing-telemetry JSON history "
                         "(consensus distance, windowed spectral gap, "
                         "realized effective diameter) to PATH")
    ap.add_argument("--hetero-alpha", type=float, default=None,
                    help="Dirichlet(alpha) data heterogeneity across nodes: "
                         "each node draws its token distribution from a "
                         "Dirichlet prior over the active vocab (small "
                         "alpha = highly non-iid, the federated setting)")
    ap.add_argument("--R", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--restore", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--active-vocab", type=int, default=64,
                    help="restrict synthetic tokens to first k ids "
                         "(learnable stream); 0 = full vocab")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    model = build(cfg)
    n = args.nodes
    R = args.R if args.algo == "mc_dsgt" else 1
    # gossip rounds one step consumes — and exactly how many we stage/stack
    # per step, so the consumed window matches the budget accounting
    wps = engine.make_rule(args.algo, gamma=args.gamma, R=R).weights_per_step
    local_opt = LOCAL_OPTS[args.local_opt]
    local_opt = local_opt() if local_opt is not None else None

    # horizon only matters for the non-periodic schedules (resampled
    # matching, mobility) and realized fault windows; the x4 cushion covers
    # --restore continuations (wrap past it is benign)
    horizon = (args.steps + 1) * wps * 4
    sched = make_weight_schedule(args.topology, n, args.beta,
                                 horizon=horizon, seed=args.seed,
                                 er_p=args.er_p, radius=args.radius)
    fault_models = make_fault_models(args)
    if fault_models:
        # ideal plan -> channel degradation -> repair -> (re-)lowering:
        # the realized window replaces the schedule wholesale, so both
        # gossip impls (dense staging AND the structured plan path below)
        # consume the same post-fault matrices
        sched = sim_faults.realize_weight_schedule(sched, fault_models,
                                                   rounds=horizon)
    telem = None
    if fault_models or args.telemetry or \
            args.topology in ("geometric-mobility", "waypoint-mobility"):
        # record only on log steps: the windowed metrics are host-side
        # numpy over (window, n, n) matrices, cheap but not free per step
        telem = sim_telemetry.TelemetryRecorder(sched, wps=wps,
                                                every=args.log_every)
    stream = token_stream_for(cfg, n, R, args.batch, args.seq, seed=args.seed,
                              active_vocab=args.active_vocab,
                              hetero_alpha=args.hetero_alpha)
    plan = sched.plan(0, sched.period) if args.gossip_impl == "auto" else None
    init_state, warm_start, train_step = dsteps.make_train_step(
        model, cfg, algo=args.algo, gamma=args.gamma, R=R,
        gossip_impl=args.gossip_impl, plan=plan, local_opt=local_opt,
        pallas_interpret=jax.default_backend() != "tpu")

    state = init_state(jax.random.key(args.seed), n, jnp.float32)
    state, start_step = driver.restore_or_warm(
        state, restore=args.restore, load_fn=load_checkpoint,
        warm=lambda s: warm_start(s, stream.batch_at(0)))
    if args.restore:
        print(f"restored step {start_step} from {args.restore}")

    # Stage the whole period's gossip tensors on device ONCE; the jitted
    # step indexes them by (t mod period) — no per-step stacked()/transfer.
    staged = driver.stage(
        sched, wps=wps, impl=("auto" if args.gossip_impl == "auto"
                              else "dense"), plan=plan,
        static_t=(args.gossip_impl == "auto"
                  and train_step.gossip_dispatch == "static"))
    if args.gossip_impl == "auto":
        step_fn = driver.bind_step(staged, train_step)
    else:
        step_fn = driver.bind_step(
            staged, lambda state, batch, W, t: train_step(state, batch, W))

    def record(k, t, state, out, dt):
        loss = float(out["loss"])
        tl = telem.record(k, t, state, out, dt) if telem is not None else None
        if k % args.log_every != 0:
            return None
        ce = tl["consensus"] if tl is not None else consensus_error(state.x)
        extra = ""
        if tl is not None:
            ed = tl["eff_diameter"]
            gap = tl["spectral_gap"]
            extra = (f"  gap {gap if gap is not None else float('nan'):.3f}"
                     f"  eff_diam {ed if ed is not None else '-'}")
        print(f"step {k:5d}  T={t:6d}  loss {loss:.4f}  "
              f"consensus {ce:.3e}{extra}  {dt:.2f}s")
        return {"step": k, "loss": loss, "consensus": ce,
                "sec": round(dt, 3)}

    state, history = driver.run_loop(
        step_fn, state, steps=args.steps, wps=wps, period=staged.period,
        start_step=start_step, extra_fn=lambda k: stream.batch_at(k + 1),
        record=record, checkpoint=args.checkpoint,
        save_fn=save_checkpoint)
    if args.checkpoint:
        print(f"saved {args.checkpoint}")
    if args.telemetry and telem is not None:
        telem.dump(args.telemetry)
        print(f"wrote telemetry {args.telemetry}")
    return history


if __name__ == "__main__":
    main()
