"""End-to-end decentralized training CLI — a thin argv -> spec translator.

Every flag maps to one field of :class:`repro.exp.ExperimentSpec` (see
``FLAG_TO_FIELD``); the run itself is ``repro.exp.run(spec)``, the same
entry the examples and benchmark sweeps call.  Choice lists (topologies,
algorithms, local optimizers, gossip impls) come from the
:mod:`repro.exp.registry` vocabularies — adding a registry entry updates
this CLI automatically.

Config files: ``--config PATH`` loads a spec JSON (a bare spec or a
reproducibility manifest) as the baseline and explicit flags override it;
``--dump-config`` prints the fully-resolved spec JSON and exits, so

    train --topology federated --algo local_sgd --dump-config > fed.json
    train --config fed.json --steps 100

round-trips any flag combination through a reviewable, versionable file.

Example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset reduced --steps 50 --nodes 8 --beta 0.875 --algo mc_dsgt --R 2

The paper's federated scenario (one rule, zero runtime edits):
    PYTHONPATH=src python -m repro.launch.train --algo local_sgd \
        --topology federated --hetero-alpha 0.1 --gossip-impl auto

The wireless scenario (repro.sim): moving nodes, lossy channel, telemetry:
    PYTHONPATH=src python -m repro.launch.train --topology geometric-mobility \
        --nodes 16 --link-drop 0.2 --gossip-impl auto --telemetry telem.json

Observability (repro.obs): JSONL event log + phase spans + optimality gap,
rendered with ``python -m repro.obs.report run.jsonl``:
    PYTHONPATH=src python -m repro.launch.train --steps 40 --algo mc_dsgt \
        --metrics run.jsonl --metrics-every 10 --obs-names auto
"""

from __future__ import annotations

import argparse

from repro import exp
from repro.exp import make_weight_schedule  # noqa: F401  (legacy import site)

# flag dest -> dotted ExperimentSpec field.  This mapping IS the CLI's
# semantics (and the README migration table): parse_args collects only the
# flags actually given (argparse.SUPPRESS), and each one overrides the
# baseline spec — the dataclass defaults, or the --config file.
FLAG_TO_FIELD = {
    "arch": "model.arch",
    "preset": "model.preset",
    "logreg_d": "model.d",
    "logreg_m": "model.m",
    "steps": "run.steps",
    "nodes": "run.nodes",
    "beta": "topology.beta",
    "topology": "topology.kind",
    "algo": "algorithm.name",
    "gossip_impl": "run.gossip_impl",
    "local_opt": "algorithm.local_opt",
    "er_p": "topology.er_p",
    "radius": "topology.radius",
    "local_steps": "topology.local_steps",
    "pods": "topology.pods",
    "sample_k": "topology.sample_k",
    "delay": "algorithm.delay",
    "comm_interval": "algorithm.comm_interval",
    "link_drop": "channel.link_drop",
    "burst_loss": "channel.burst_loss",
    "churn": "channel.churn",
    "straggler": "channel.straggler",
    "telemetry": "run.telemetry",
    "compress": "compression.scheme",
    "compress_group": "compression.group",
    "compress_warmup": "compression.warmup",
    "error_feedback": "compression.error_feedback",
    "hetero_alpha": "data.hetero_alpha",
    "R": "algorithm.R",
    "gamma": "algorithm.gamma",
    "batch": "data.batch",
    "seq": "data.seq",
    "checkpoint": "run.checkpoint",
    "restore": "run.restore",
    "log_every": "run.log_every",
    "active_vocab": "data.active_vocab",
    "seed": "run.seed",
    "metrics": "obs.metrics",
    "metrics_every": "obs.every",
    "obs_names": "obs.names",
    "profile_dir": "obs.profile_dir",
    "profile_steps": "obs.profile_steps",
}


def build_parser() -> argparse.ArgumentParser:
    # SUPPRESS: a flag appears in the namespace only when explicitly given,
    # so file-provided values are overridden by flags and nothing else.
    ap = argparse.ArgumentParser(argument_default=argparse.SUPPRESS)
    ap.add_argument("--config", metavar="PATH",
                    help="baseline spec JSON (a spec or a manifest written "
                         "by a previous run); explicit flags override it")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the fully-resolved spec JSON and exit "
                         "(pipe to a file, rerun with --config)")
    ap.add_argument("--arch",
                    help="registered LM architecture (repro.configs), or "
                         "'logreg' for the paper's host-runtime logistic "
                         "regression (required by --topology random-sampled)")
    ap.add_argument("--preset", choices=["reduced", "full"])
    ap.add_argument("--logreg-d", type=int, dest="logreg_d",
                    help="--arch logreg: feature dimension (default 64; "
                         "keep small at 10^5+ nodes — the dataset is "
                         "n x m x d)")
    ap.add_argument("--logreg-m", type=int, dest="logreg_m",
                    help="--arch logreg: samples per node (default 256)")
    ap.add_argument("--steps", type=int)
    ap.add_argument("--nodes", type=int)
    ap.add_argument("--beta", type=float)
    ap.add_argument("--topology", choices=list(exp.TOPOLOGIES))
    ap.add_argument("--algo", choices=list(exp.ALGORITHMS))
    ap.add_argument("--gossip-impl", choices=list(exp.GOSSIP_IMPLS),
                    help="multi-consensus path: GSPMD einsum (dense), the "
                         "fused Pallas gossip_mix kernel (interpret-mode "
                         "fallback on CPU), or per-round structured dispatch "
                         "from the gossip plan (auto: sun / matching / "
                         "complete lowerings, dense fallback)")
    ap.add_argument("--local-opt", choices=sorted(exp.LOCAL_OPTS),
                    help="local-optimizer transform applied to the descent "
                         "direction (repro.optim; sgd = the paper-pure "
                         "update, no transform)")
    ap.add_argument("--er-p", type=float,
                    help="edge probability for --topology erdos-renyi")
    ap.add_argument("--radius", type=float,
                    help="unit-disk communication range for the mobility "
                         "topologies (geometric-mobility, waypoint-mobility)")
    ap.add_argument("--local-steps", type=int,
                    help="local-only rounds between averaging rounds for "
                         "--topology federated")
    ap.add_argument("--pods", type=int,
                    help="nodes per pod (pod-major order): rounds that "
                         "factor as B ⊗ J_p across pod boundaries take the "
                         "hierarchical two-level lowering under --gossip-impl "
                         "auto; --topology hierarchical builds such schedules")
    ap.add_argument("--sample-k", type=int, dest="sample_k",
                    help="clients gossiping per round for --topology "
                         "random-sampled (the sparse edge-list family: "
                         "per-round cost O(edges), n can reach 10^5..10^6)")
    ap.add_argument("--delay", type=int,
                    help="stale-window gossip: mix the payload from N steps "
                         "ago and fold only the correction into the fresh "
                         "payload, freeing XLA to overlap the collectives "
                         "with the grad computation (0 = synchronous, "
                         "bit-exact today's path)")
    ap.add_argument("--comm-interval", type=int,
                    help="mix every k driver steps, pure local updates in "
                         "between (identity mix on skipped steps; "
                         "incompatible with --compress)")
    ap.add_argument("--link-drop", type=float,
                    help="iid per-round per-link Bernoulli drop probability "
                         "(repro.sim channel degradation)")
    ap.add_argument("--burst-loss", type=float,
                    help="Gilbert-Elliott bursty loss: per-round good->bad "
                         "transition probability (bad links drop their "
                         "round; recovery 0.25/round)")
    ap.add_argument("--churn", type=float,
                    help="per-round node failure probability (a down node "
                         "loses all links; recovery 0.3/round)")
    ap.add_argument("--straggler", type=float,
                    help="per-round per-node straggler probability (a "
                         "straggler's links miss the round deadline and "
                         "are dropped)")
    ap.add_argument("--telemetry", metavar="PATH",
                    help="write the repro.sim mixing-telemetry JSON history "
                         "(consensus distance, windowed spectral gap, "
                         "realized effective diameter) to PATH")
    ap.add_argument("--compress", choices=list(exp.COMPRESSIONS),
                    help="gossip payload compression scheme: sign (1 "
                         "bit/entry + one f32 scale per group) or int8 "
                         "(absmax per group), with per-node error-feedback "
                         "residuals; none = full-precision f32 payloads")
    ap.add_argument("--compress-group", type=int,
                    help="entries per quantization scale group "
                         "(default 256)")
    ap.add_argument("--compress-warmup", type=int,
                    help="driver steps that gossip at full precision "
                         "before the compression scheme activates")
    ap.add_argument("--no-error-feedback", dest="error_feedback",
                    action="store_false",
                    help="disable the error-feedback residual (pure "
                         "quantized gossip; EF is on by default)")
    ap.add_argument("--hetero-alpha", type=float,
                    help="Dirichlet(alpha) data heterogeneity across nodes: "
                         "each node draws its token distribution from a "
                         "Dirichlet prior over the active vocab (small "
                         "alpha = highly non-iid, the federated setting)")
    ap.add_argument("--R", type=int)
    ap.add_argument("--gamma", type=float)
    ap.add_argument("--batch", type=int)
    ap.add_argument("--seq", type=int)
    ap.add_argument("--checkpoint")
    ap.add_argument("--restore")
    ap.add_argument("--log-every", type=int)
    ap.add_argument("--active-vocab", type=int,
                    help="restrict synthetic tokens to first k ids "
                         "(learnable stream); 0 = full vocab")
    ap.add_argument("--seed", type=int)
    ap.add_argument("--metrics", metavar="PATH",
                    help="write the repro.obs JSONL event log (in-jit step "
                         "metrics, phase spans, optimality gap) to PATH; "
                         "render it with `python -m repro.obs.report PATH`")
    ap.add_argument("--metrics-every", type=int,
                    help="host flush batch for --metrics: buffered device "
                         "scalars cross the host boundary once per N "
                         "recorded steps (default 10)")
    ap.add_argument("--obs-names",
                    help="comma-separated in-jit metric subset for "
                         f"--metrics (of: {', '.join(exp.OBS_METRICS)}); "
                         "'auto' = the update rule's default set")
    ap.add_argument("--profile-dir", metavar="DIR",
                    help="dump a jax profiler trace of the first "
                         "--profile-steps steps into DIR")
    ap.add_argument("--profile-steps", type=int)
    ap.add_argument("--quiet", action="store_true", default=False,
                    help="suppress progress output (event-log/telemetry "
                         "files are still written)")
    return ap


def spec_from_args(args: argparse.Namespace) -> exp.ExperimentSpec:
    """Translate a parsed namespace into a spec: start from the --config
    baseline (or the dataclass defaults) and apply each explicitly-given
    flag through its ``FLAG_TO_FIELD`` path."""
    spec = exp.load(args.config) if getattr(args, "config", None) \
        else exp.ExperimentSpec()
    overrides = {FLAG_TO_FIELD[dest]: value
                 for dest, value in vars(args).items()
                 if dest in FLAG_TO_FIELD}
    # ``--arch logreg`` selects the paper's host-runtime logistic
    # regression (model.kind), not a registered LM architecture — the
    # required model for the sparse sampled-client topologies.
    if overrides.get("model.arch") == "logreg":
        del overrides["model.arch"]
        overrides["model.kind"] = "logreg"
    return exp.with_overrides(spec, overrides)


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args)
    if getattr(args, "dump_config", False):
        print(exp.to_json(spec, elide_defaults=False))
        return spec
    return exp.run(spec, quiet=args.quiet).history


if __name__ == "__main__":
    main()
