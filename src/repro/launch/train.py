"""End-to-end decentralized training driver.

Runs MC-DSGT / DSGT / DSGD over a time-varying topology schedule on any
registered architecture (reduced or full), with checkpointing and loss /
consensus logging.  On the CPU container this runs the reduced configs; on
a real TPU pod, pass --mesh production to shard over the 16x16 mesh.

Example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset reduced --steps 50 --nodes 8 --beta 0.875 --algo mc_dsgt --R 2
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import gossip, topology as topo
from repro.data import token_stream_for
from repro.dist import collectives as dcoll, steps as dsteps
from repro.models import build


def make_weight_schedule(kind: str, n: int, beta: float, *,
                         horizon: int | None = None, seed: int = 0,
                         er_p: float = 0.5) -> gossip.WeightSchedule:
    """Build the weight schedule for one named topology scenario.

    ``horizon`` (total gossip rounds the run will consume) is required only
    by the non-periodic ``resampled-matching`` schedule; ``er_p`` is the
    Erdős–Rényi edge probability."""
    if kind == "sun":
        return gossip.theorem3_weight_schedule(n, beta)
    if kind == "one-peer-exp":
        return gossip.schedule_from_topology(topo.one_peer_exponential_schedule(n))
    if kind == "ring":
        return gossip.schedule_from_topology(topo.StaticSchedule(topo.ring_graph(n)))
    if kind == "static-exp":
        return gossip.schedule_from_topology(
            topo.StaticSchedule(topo.static_exponential_graph(n)))
    if kind == "federated":
        return gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    if kind == "random-matching":
        return gossip.schedule_from_topology(topo.random_matching_schedule(n))
    if kind == "resampled-matching":
        return gossip.schedule_from_topology(
            topo.resampled_matching_schedule(n, seed=seed), horizon=horizon)
    if kind == "erdos-renyi":
        return gossip.schedule_from_topology(
            topo.erdos_renyi_schedule(n, er_p, seed=seed))
    if kind == "complete":
        return gossip.WeightSchedule((np.ones((n, n)) / n,))
    raise ValueError(kind)

TOPOLOGIES = ["sun", "ring", "one-peer-exp", "static-exp", "federated",
              "complete", "random-matching", "resampled-matching",
              "erdos-renyi"]


def consensus_error(x) -> float:
    tot = 0.0
    for leaf in jax.tree.leaves(x):
        xb = jnp.mean(leaf, axis=0, keepdims=True)
        tot += float(jnp.sum((leaf - xb) ** 2))
    return tot ** 0.5


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.75)
    ap.add_argument("--topology", default="sun", choices=TOPOLOGIES)
    ap.add_argument("--algo", default="mc_dsgt",
                    choices=["mc_dsgt", "dsgt", "dsgd", "d2"])
    ap.add_argument("--gossip-impl", default="dense",
                    choices=["dense", "pallas", "auto"],
                    help="multi-consensus path: GSPMD einsum (dense), the "
                         "fused Pallas gossip_mix kernel (interpret-mode "
                         "fallback on CPU), or per-round structured dispatch "
                         "from the gossip plan (auto: sun / matching / "
                         "complete lowerings, dense fallback)")
    ap.add_argument("--er-p", type=float, default=0.5,
                    help="edge probability for --topology erdos-renyi")
    ap.add_argument("--R", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--restore", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--active-vocab", type=int, default=64,
                    help="restrict synthetic tokens to first k ids "
                         "(learnable stream); 0 = full vocab")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    model = build(cfg)
    n = args.nodes
    R = args.R if args.algo == "mc_dsgt" else 1
    # gossip rounds one step consumes — and exactly how many we stage/stack
    # per step, so the consumed window matches the budget accounting
    wps = {"dsgd": R, "d2": 1}.get(args.algo, 2 * R)

    # horizon only matters for the non-periodic resampled-matching schedule;
    # the x4 cushion covers --restore continuations (wrap past it is benign)
    horizon = (args.steps + 1) * wps * 4
    sched = make_weight_schedule(args.topology, n, args.beta,
                                 horizon=horizon, seed=args.seed,
                                 er_p=args.er_p)
    stream = token_stream_for(cfg, n, R, args.batch, args.seq, seed=args.seed,
                              active_vocab=args.active_vocab)
    plan = sched.plan(0, sched.period) if args.gossip_impl == "auto" else None
    init_state, warm_start, train_step = dsteps.make_train_step(
        model, cfg, algo=args.algo, gamma=args.gamma, R=R,
        gossip_impl=args.gossip_impl, plan=plan,
        pallas_interpret=jax.default_backend() != "tpu")

    state = init_state(jax.random.key(args.seed), n, jnp.float32)
    start_step = 0
    if args.restore:
        state, start_step = load_checkpoint(args.restore, state)
        print(f"restored step {start_step} from {args.restore}")
    else:
        state = warm_start(state, stream.batch_at(0))

    # Stage the whole period's gossip tensors on device ONCE; the jitted
    # step indexes them by (t mod period) — no per-step stacked()/transfer.
    period = sched.period
    if args.gossip_impl == "auto":
        gossip_dev = dcoll.stage_plan(plan)
        static_t = train_step.gossip_dispatch == "static"
        step_fn = (jax.jit(train_step, static_argnums=3) if static_t
                   else jax.jit(train_step))
    else:
        gossip_dev = jnp.asarray(sched.stacked(0, period))

        def _gathered_step(state, batch, Ws_all, t):
            idx = (t + jnp.arange(wps)) % period
            return train_step(state, batch, jnp.take(Ws_all, idx, axis=0))

        step_fn = jax.jit(_gathered_step)

    t = start_step * wps
    history = []
    for k in range(start_step, start_step + args.steps):
        batch = stream.batch_at(k + 1)
        t0 = time.time()
        state, metrics = step_fn(state, batch, gossip_dev, t % period)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        t += wps
        if k % args.log_every == 0:
            ce = consensus_error(state.x)
            history.append({"step": k, "loss": loss, "consensus": ce,
                            "sec": round(dt, 3)})
            print(f"step {k:5d}  T={t:6d}  loss {loss:.4f}  "
                  f"consensus {ce:.3e}  {dt:.2f}s")
        if args.checkpoint and (k + 1) % 50 == 0:
            save_checkpoint(args.checkpoint, state, k + 1)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state, start_step + args.steps)
        print(f"saved {args.checkpoint}")
    return history


if __name__ == "__main__":
    main()
