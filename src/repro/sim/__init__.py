"""repro.sim — network dynamics, channel faults, and mixing telemetry.

The scenario engine the paper's premise calls for: generate physically
motivated time-varying networks (wireless mobility), degrade them with
lossy/bursty channels, node churn and stragglers, repair the surviving
links into valid mixing matrices, and measure online what the realized
schedule does to consensus (see README "channel → repair → lowering").
"""

from .channel import (  # noqa: F401
    BernoulliDropChannel,
    GilbertElliottChannel,
    LinkLatencyModel,
)
from .faults import (  # noqa: F401
    NodeChurn,
    StragglerInjection,
    combined_mask,
    realize_weight_schedule,
    repair_weights,
)
from .mobility import (  # noqa: F401
    RandomGeometricSchedule,
    RandomWaypointSchedule,
    random_geometric_schedule,
    random_waypoint_schedule,
    unit_disk_adjacency,
)
from .telemetry import (  # noqa: F401
    TELEMETRY_FIELDS,
    TelemetryRecorder,
    consensus_distance,
    empirical_effective_diameter,
    windowed_spectral_gap,
)
