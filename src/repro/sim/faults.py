"""Node churn, straggler injection, and weight-matrix repair.

The data path this module anchors (see README "channel → repair →
lowering"): an *ideal* weight schedule W^t (built from any topology by
:func:`repro.core.gossip.schedule_from_topology`) is degraded by one or
more link/node fault models (:mod:`repro.sim.channel` and the classes
here), the surviving links are *repaired* back into a valid mixing matrix
by :func:`repair_weights`, and the realized per-round matrices flow through
the existing :meth:`repro.core.gossip.WeightSchedule.plan` lowering — a
degraded matching still takes the cheap one-peer/ppermute path and a fully
dropped round lowers to a free ``empty`` round — on both the host runtime
(:func:`repro.core.algorithms.run`) and the distributed runtime
(:mod:`repro.dist.steps`, plan tensors staged once).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core import gossip, topology as topo
from . import channel as chan, hashrand

_CHURN_BLOCK_TAG = 0xC0
_CHURN_STEP_TAG = 0xC1
_STRAGGLER_TAG = 0x57

# Counter-hash tags for the edge-list query path (O(edges) sparse
# scenarios): distinct streams from the dense draws above, equal in
# distribution but not bitwise equal — see repro.sim.channel.
_CHURN_EDGE_BLOCK_TAG = 0xC2
_CHURN_EDGE_STEP_TAG = 0xC3
_STRAGGLER_EDGE_TAG = 0x58


def repair_weights(W: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Renormalize the surviving links of ``W`` back to a mixing matrix.

    Off-diagonal weight on dropped links moves to the sender's diagonal
    (the "lazy" repair: a node that hears nothing from a peer keeps that
    share of its own value) — exactly what the partial-averaging protocol
    does physically when a message is lost and the receiver reuses its own
    state for the missing summand.

    For symmetric ``W`` and a symmetric ``mask`` the repaired matrix is
    again symmetric and doubly stochastic, so it passes
    :func:`repro.core.gossip.check_assumption3` on the realized sparsity
    pattern.  A *directed* (asymmetric) mask yields the documented
    row-stochastic fallback: every row still sums to 1 (each node performs
    a convex combination of what it received) but columns need not — such
    matrices are usable by row-stochastic gossip variants only, and
    :func:`realize_weight_schedule` therefore symmetrizes every mask.
    """
    W = np.asarray(W, np.float64)
    n = W.shape[0]
    eye = np.eye(n, dtype=bool)
    keep = np.asarray(mask, bool) & ~eye
    out = np.where(keep, W, 0.0)
    lost = np.where(~keep & ~eye, W, 0.0).sum(axis=1)
    out[eye] = W[eye] + lost
    return out


def repair_edges(w: np.ndarray, keep: np.ndarray) -> np.ndarray:
    """:func:`repair_weights` on an edge list: ``w[keep]``.

    In the Laplacian edge form (:mod:`repro.sparse.plan`, diagonal implied
    as ``1 - rowsum``) dropping an edge IS the lazy repair — the lost
    weight returns to both endpoints' diagonals by construction, with no
    densification and no renormalization pass.  This one-liner exists to
    make that contract explicit (and testable) next to the dense repair.
    """
    return np.asarray(w)[np.asarray(keep, bool)]


@dataclasses.dataclass(frozen=True)
class NodeChurn:
    """Node up/down churn: each node runs a 2-state Markov chain (up/down)
    with per-round failure probability ``p_fail`` and recovery probability
    ``p_recover``.  A down node loses ALL its links for the round (its
    repaired row degenerates to the self-loop).  Random access uses the
    same block-regeneration trick as the Gilbert–Elliott channel."""

    p_fail: float
    p_recover: float = 0.3
    seed: int = 0
    block: int = 64

    def alive(self, t: int, n: int) -> np.ndarray:
        denom = self.p_fail + self.p_recover
        pi_down = self.p_fail / denom if denom > 0 else 0.0
        b0 = (t // self.block) * self.block
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.seed, _CHURN_BLOCK_TAG, t // self.block)))
        down = rng.random(n) < pi_down
        for r in range(b0 + 1, t + 1):
            rng = np.random.default_rng(
                np.random.SeedSequence((self.seed, _CHURN_STEP_TAG, r)))
            u = rng.random(n)
            down = np.where(down, u < 1.0 - self.p_recover, u < self.p_fail)
        return ~down

    def mask(self, t: int, n: int) -> np.ndarray:
        a = self.alive(t, n)
        m = a[:, None] & a[None, :]
        np.fill_diagonal(m, True)
        return m

    def node_alive(self, t: int, nodes) -> np.ndarray:
        """Alive bits for the queried node ids only — the same block-regen
        chain as :meth:`alive` on its own hash stream, O(|nodes| * block)."""
        nodes = np.asarray(nodes)
        denom = self.p_fail + self.p_recover
        pi_down = self.p_fail / denom if denom > 0 else 0.0
        b0 = (t // self.block) * self.block
        down = hashrand.counter_uniform(
            self.seed, _CHURN_EDGE_BLOCK_TAG, t // self.block, nodes) < pi_down
        for r in range(b0 + 1, t + 1):
            u = hashrand.counter_uniform(self.seed, _CHURN_EDGE_STEP_TAG,
                                         r, nodes)
            down = np.where(down, u < 1.0 - self.p_recover, u < self.p_fail)
        return ~down

    def edge_mask(self, t: int, src, dst) -> np.ndarray:
        src, dst = np.asarray(src), np.asarray(dst)
        alive = self.node_alive(t, np.stack([src, dst]))
        return (alive[0] & alive[1]) | (src == dst)


@dataclasses.dataclass(frozen=True)
class StragglerInjection:
    """Straggler injection: each node straggles at round t with probability
    ``prob`` (iid per round), multiplying the latency of every link it
    touches by ``slowdown``; a link whose realized latency
    (:class:`repro.sim.channel.LinkLatencyModel`) exceeds ``deadline``
    misses the round and is treated as dropped.  With the default latency
    model a healthy link (~1.0 nominal) comfortably makes the 2.5x
    deadline, a straggler's 4x link does not — so ``prob`` is effectively
    the per-node straggle rate, with a natural heavy-latency tail on top."""

    prob: float
    slowdown: float = 4.0
    deadline: float = 2.5
    latency: chan.LinkLatencyModel = None
    seed: int = 0

    def mask(self, t: int, n: int) -> np.ndarray:
        lat_model = self.latency or chan.LinkLatencyModel(seed=self.seed)
        lat = lat_model.sample(t, n)
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _STRAGGLER_TAG, t)))
        slow = rng.random(n) < self.prob
        factor = np.where(slow, self.slowdown, 1.0)
        # a link is as slow as its slowest endpoint
        eff = lat * np.maximum(factor[:, None], factor[None, :])
        m = eff <= self.deadline
        np.fill_diagonal(m, True)
        return m

    def edge_mask(self, t: int, src, dst) -> np.ndarray:
        """(E,) deadline mask for queried edges — per-node straggle bits
        and per-edge latencies from their own hash streams."""
        src, dst = np.asarray(src), np.asarray(dst)
        lat_model = self.latency or chan.LinkLatencyModel(seed=self.seed)
        lat = lat_model.edge_sample(t, src, dst)
        slow = hashrand.counter_uniform(self.seed, _STRAGGLER_EDGE_TAG,
                                        t, np.stack([src, dst])) < self.prob
        factor = np.where(slow, self.slowdown, 1.0)
        eff = lat * np.maximum(factor[0], factor[1])
        return (eff <= self.deadline) | (src == dst)


def combined_mask(models: Sequence, t: int, n: int) -> np.ndarray:
    """AND of every model's survival mask, symmetrized (a link needs both
    directions to count as alive — see :func:`repair_weights`), diagonal
    forced True."""
    m = np.ones((n, n), dtype=bool)
    for model in models:
        m &= np.asarray(model.mask(t, n), bool)
    m &= m.T
    np.fill_diagonal(m, True)
    return m


def combined_edge_mask(models: Sequence, t: int, src, dst) -> np.ndarray:
    """AND of every model's edge-level survival mask, O(edges).

    Symmetry needs no extra pass: every ``edge_mask`` hashes canonical
    (lo, hi) endpoint keys, so both directed entries of an undirected edge
    get the same draw."""
    src, dst = np.asarray(src), np.asarray(dst)
    m = np.ones(src.shape, dtype=bool)
    for model in models:
        m &= np.asarray(model.edge_mask(t, src, dst), bool)
    return m | (src == dst)


def realize_weight_schedule(ideal: gossip.WeightSchedule,
                            models: Sequence,
                            rounds: int | None = None,
                            t0: int = 0) -> gossip.WeightSchedule:
    """Materialize the *realized* post-fault weight schedule.

    For each round t in [t0, t0 + rounds): apply every fault model's mask
    to the ideal matrix W^t, repair the survivors
    (:func:`repair_weights`), and re-classify the realized sparsity so the
    gossip planner lowers each round to its cheapest surviving collective
    (degraded matching → ``matching`` with fixed points, everything dropped
    → ``empty``).  Returns a plain :class:`repro.core.gossip.WeightSchedule`
    whose period is the materialized window — callers size ``rounds`` to at
    least the run's total gossip budget, exactly like the non-periodic
    topology schedules."""
    rounds = ideal.period if rounds is None else rounds
    n = ideal.n
    mats, structs = [], []
    for r in range(rounds):
        t = t0 + r
        mask = combined_mask(models, t, n)
        W = repair_weights(ideal(t), mask)
        adj = np.abs(W) > 1e-12
        np.fill_diagonal(adj, True)
        mats.append(W)
        structs.append(topo.classify_adjacency(adj))
    return gossip.WeightSchedule(tuple(mats), tuple(structs))
