"""Counter-based random streams for edge-list scenario code.

The dense channel/fault models draw from ``np.random.SeedSequence((seed,
TAG, t))`` generator streams, which is pure in ``(seed, t)`` but only
*sequentially* accessible: materializing a draw for one link requires
drawing the whole (n, n) matrix.  The sparse scenario engine operates on
edge lists where n can be 10^5-10^6 and only O(edges) work is allowed per
round, so it needs *random access*: "the uniform for link (i, j) at round
t" as a pure function of ``(seed, tag, t, i, j)`` with no per-round state.

This module provides that: a vectorized splitmix64-style counter hash
mapping integer key tuples to iid U[0,1) / N(0,1) draws.  Streams here are
equal *in distribution* to the dense generator streams but NOT bitwise
equal to them — each edge-level model method documents that it is a
distinct stream keyed by a distinct tag.
"""

from __future__ import annotations

import numpy as np

_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_INV_2_53 = 1.0 / float(1 << 53)


def _splitmix(z: np.ndarray) -> np.ndarray:
    """The splitmix64 finalizer, vectorized over uint64 arrays."""
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


def counter_hash(seed: int, tag: int, *keys) -> np.ndarray:
    """Hash ``(seed, tag, *keys)`` to uint64; keys broadcast as arrays."""
    with np.errstate(over="ignore"):
        h = _splitmix(np.uint64(int(seed) & 0xFFFFFFFFFFFFFFFF)
                      ^ (np.uint64(int(tag)) * _GOLDEN))
        for k in keys:
            k64 = np.asarray(k).astype(np.uint64)
            h = _splitmix(h ^ (k64 * _GOLDEN + _MIX1))
    return h


def counter_uniform(seed: int, tag: int, *keys) -> np.ndarray:
    """iid U[0, 1) draws, one per broadcast element of ``keys``."""
    return (counter_hash(seed, tag, *keys) >> np.uint64(11)).astype(
        np.float64) * _INV_2_53


def counter_normal(seed: int, tag: int, *keys) -> np.ndarray:
    """iid N(0, 1) via Box-Muller on two sub-streams of the same keys."""
    u1 = counter_uniform(seed, tag, *keys, 0)
    u2 = counter_uniform(seed, tag, *keys, 1)
    u1 = np.maximum(u1, 1e-300)  # log(0) guard; probability ~2^-53
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


def edge_canonical(src, dst):
    """Canonical (lo, hi) endpoint order so undirected-link draws are
    symmetric: both directed entries of an edge hash to the same keys."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    return np.minimum(src, dst), np.maximum(src, dst)
