"""Wireless node mobility producing unit-disk time-varying topologies.

The paper motivates time-varying networks physically: gossip algorithms are
"more robust in wireless scenarios especially when nodes are moving".  This
module generates those scenarios: nodes move in the unit square and a
directed link (j, i) is active at round t iff ||p_i^t - p_j^t|| <= radius
(the unit-disk model), giving a symmetric time-varying adjacency schedule
that plugs into :func:`repro.core.gossip.schedule_from_topology` like every
hand-authored construction.

Both schedules follow the :class:`repro.core.topology.ResampledMatchingSchedule`
pattern — ``period is None`` and every round is a pure function of
``(seed, t)`` drawn from a :class:`numpy.random.SeedSequence` stream, so
out-of-order and repeated ``__call__``/``structure(t)`` queries return
identical rounds (the determinism regression tests pin this).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import topology as topo

# SeedSequence domain tags: keep the mobility streams disjoint from each
# other and from every channel/fault stream (see repro.sim.channel).
_GEOMETRIC_TAG = 0x6E0
_WAYPOINT_TAG = 0x3A7


def unit_disk_adjacency(positions: np.ndarray, radius: float) -> topo.Adjacency:
    """Symmetric unit-disk graph over ``positions`` (n, 2): link iff the
    Euclidean distance is <= ``radius``; self-loops on the diagonal."""
    d2 = ((positions[:, None, :] - positions[None, :, :]) ** 2).sum(-1)
    adj = d2 <= radius * radius
    np.fill_diagonal(adj, True)
    return adj


@dataclasses.dataclass(frozen=True)
class RandomGeometricSchedule:
    """iid random-geometric motion: every round samples fresh uniform
    positions in [0, 1]^2 (a node "teleports" between rounds — the
    memoryless extreme of mobility; :class:`RandomWaypointSchedule` is the
    temporally-correlated one)."""

    n: int
    radius: float = 0.45
    seed: int = 0

    period = None  # non-periodic: every round is a fresh draw

    def positions(self, t: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _GEOMETRIC_TAG, t)))
        return rng.random((self.n, 2))

    def __call__(self, t: int) -> topo.Adjacency:
        return unit_disk_adjacency(self.positions(t), self.radius)

    def structure(self, t: int) -> topo.RoundStructure:
        return topo.classify_adjacency(self(t))


@dataclasses.dataclass(frozen=True)
class RandomWaypointSchedule:
    """Random-waypoint motion: each node travels in a straight line from
    waypoint to waypoint; leg k occupies rounds [k*leg_rounds, (k+1)*leg_rounds)
    and the position interpolates linearly along it.  Waypoints are drawn
    from a seed stream keyed by ``(seed, leg)``, so ``positions(t)`` is
    closed-form in t — no sequential simulation state, hence out-of-order
    determinism.  (The classic formulation moves at constant *speed*; fixing
    the leg *duration* instead keeps random access O(1) while preserving the
    temporally-correlated adjacency the model exists for.)"""

    n: int
    radius: float = 0.45
    leg_rounds: int = 8
    seed: int = 0

    period = None

    def _waypoints(self, leg: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _WAYPOINT_TAG, leg)))
        return rng.random((self.n, 2))

    def positions(self, t: int) -> np.ndarray:
        leg, r = divmod(int(t), self.leg_rounds)
        a = self._waypoints(leg)
        b = self._waypoints(leg + 1)
        return a + (b - a) * (r / self.leg_rounds)

    def __call__(self, t: int) -> topo.Adjacency:
        return unit_disk_adjacency(self.positions(t), self.radius)

    def structure(self, t: int) -> topo.RoundStructure:
        return topo.classify_adjacency(self(t))


def random_geometric_schedule(n: int, radius: float = 0.45,
                              seed: int = 0) -> RandomGeometricSchedule:
    if not 0.0 < radius:
        raise ValueError(f"radius must be positive, got {radius}")
    return RandomGeometricSchedule(n, radius, seed)


def random_waypoint_schedule(n: int, radius: float = 0.45,
                             leg_rounds: int = 8,
                             seed: int = 0) -> RandomWaypointSchedule:
    if not 0.0 < radius:
        raise ValueError(f"radius must be positive, got {radius}")
    if leg_rounds < 1:
        raise ValueError(f"leg_rounds must be >= 1, got {leg_rounds}")
    return RandomWaypointSchedule(n, radius, leg_rounds, seed)
