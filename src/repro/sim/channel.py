"""Link-level channel degradation: drop models and latency sampling.

Every model exposes ``mask(t, n) -> (n, n) bool`` — True means the link
*survives* round t.  Masks are symmetric (a failed link fails in both
directions: without the reverse path there is no ACK, so the undirected
gossip edge is gone) and the diagonal is always True (a node can always
"talk" to itself).  Like the mobility schedules, every mask is a pure
function of ``(seed, t)`` drawn from :class:`numpy.random.SeedSequence`
streams, so out-of-order and repeated queries are deterministic.

Models
------
* :class:`BernoulliDropChannel` — iid per-round, per-link loss;
* :class:`GilbertElliottChannel` — the classic 2-state bursty-loss chain
  (good/bad per link, losses cluster while a link sits in the bad state);
* :class:`LinkLatencyModel` — per-link lognormal latency samples, consumed
  by the straggler injection in :mod:`repro.sim.faults` (links that miss
  the round deadline are treated as dropped).

The degraded links feed :func:`repro.sim.faults.repair_weights`, which
renormalizes the surviving links back to a valid mixing matrix.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import hashrand

# SeedSequence domain tags (disjoint per stream; see repro.sim.mobility).
_BERNOULLI_TAG = 0xB0
_GE_BLOCK_TAG = 0x6E
_GE_STEP_TAG = 0x6F
_GE_LOSS_TAG = 0x70
_LATENCY_TAG = 0x1A7

# Counter-hash tags for the edge-list query path (``edge_mask``).  These
# are separate streams from the dense ``mask`` draws above: equal in
# distribution, NOT bitwise equal — the dense path draws whole (n, n)
# matrices from generator streams, the edge path random-accesses one
# uniform per (t, link) so sparse scenarios stay O(edges) per round.
_BERNOULLI_EDGE_TAG = 0xB1
_GE_EDGE_BLOCK_TAG = 0x71
_GE_EDGE_STEP_TAG = 0x72
_GE_EDGE_LOSS_TAG = 0x73
_LATENCY_EDGE_TAG = 0x1A8


def _symmetric_uniform(rng: np.random.Generator, n: int) -> np.ndarray:
    """(n, n) uniforms with u[i, j] == u[j, i] (one draw per undirected
    link; the diagonal is 0)."""
    u = np.triu(rng.random((n, n)), 1)
    return u + u.T


def _symmetric_normal(rng: np.random.Generator, n: int) -> np.ndarray:
    z = np.triu(rng.normal(size=(n, n)), 1)
    return z + z.T


@dataclasses.dataclass(frozen=True)
class BernoulliDropChannel:
    """iid loss: every undirected link drops independently with probability
    ``drop`` at every round."""

    drop: float
    seed: int = 0

    def mask(self, t: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _BERNOULLI_TAG, t)))
        m = _symmetric_uniform(rng, n) >= self.drop
        np.fill_diagonal(m, True)
        return m

    def edge_mask(self, t: int, src, dst) -> np.ndarray:
        """(E,) survival mask for the queried directed edges — O(E), its
        own hash stream (see the edge-tag note at module top).  Symmetric
        in the endpoints; ``src == dst`` entries always survive."""
        lo, hi = hashrand.edge_canonical(src, dst)
        u = hashrand.counter_uniform(self.seed, _BERNOULLI_EDGE_TAG, t, lo, hi)
        return (u >= self.drop) | (lo == hi)


@dataclasses.dataclass(frozen=True)
class GilbertElliottChannel:
    """Gilbert–Elliott bursty loss: each undirected link carries a 2-state
    Markov chain (good/bad).  Transition good→bad with probability
    ``p_bad`` and bad→good with ``p_good`` per round; a link in the bad
    state drops the round with probability ``drop_bad`` (``drop_good`` in
    the good state), so losses arrive in bursts of mean length 1/p_good.

    Random access: the chain regenerates to its stationary law at every
    ``block`` boundary, so the state at round t is reconstructed by
    iterating only ``t mod block`` transitions — still a pure function of
    ``(seed, t)`` (queries out of order or repeated agree exactly), with
    bounded work per query.  Burst correlation is preserved within blocks
    and only the (already memoryless-in-distribution) cross-block coupling
    is cut.
    """

    p_bad: float
    p_good: float = 0.25
    drop_good: float = 0.0
    drop_bad: float = 1.0
    seed: int = 0
    block: int = 64

    def bad_state(self, t: int, n: int) -> np.ndarray:
        """(n, n) bool: which links sit in the bad state at round t."""
        denom = self.p_bad + self.p_good
        pi_bad = self.p_bad / denom if denom > 0 else 0.0
        b0 = (t // self.block) * self.block
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.seed, _GE_BLOCK_TAG, t // self.block)))
        bad = _symmetric_uniform(rng, n) < pi_bad
        for r in range(b0 + 1, t + 1):
            rng = np.random.default_rng(
                np.random.SeedSequence((self.seed, _GE_STEP_TAG, r)))
            u = _symmetric_uniform(rng, n)
            bad = np.where(bad, u < 1.0 - self.p_good, u < self.p_bad)
        return bad

    def mask(self, t: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _GE_LOSS_TAG, t)))
        u = _symmetric_uniform(rng, n)
        drop = np.where(self.bad_state(t, n),
                        u < self.drop_bad, u < self.drop_good)
        np.fill_diagonal(drop, False)
        return ~drop

    def edge_bad_state(self, t: int, lo, hi) -> np.ndarray:
        """Bad-state bits for canonical edge keys — the same block-regen
        chain as :meth:`bad_state`, iterated over only the queried edges
        (O(E * block) hash evaluations, n-independent)."""
        denom = self.p_bad + self.p_good
        pi_bad = self.p_bad / denom if denom > 0 else 0.0
        b0 = (t // self.block) * self.block
        u0 = hashrand.counter_uniform(self.seed, _GE_EDGE_BLOCK_TAG,
                                      t // self.block, lo, hi)
        bad = u0 < pi_bad
        for r in range(b0 + 1, t + 1):
            u = hashrand.counter_uniform(self.seed, _GE_EDGE_STEP_TAG,
                                         r, lo, hi)
            bad = np.where(bad, u < 1.0 - self.p_good, u < self.p_bad)
        return bad

    def edge_mask(self, t: int, src, dst) -> np.ndarray:
        """(E,) survival mask over queried edges — its own hash stream."""
        lo, hi = hashrand.edge_canonical(src, dst)
        u = hashrand.counter_uniform(self.seed, _GE_EDGE_LOSS_TAG, t, lo, hi)
        drop = np.where(self.edge_bad_state(t, lo, hi),
                        u < self.drop_bad, u < self.drop_good)
        return ~drop | (lo == hi)


@dataclasses.dataclass(frozen=True)
class LinkLatencyModel:
    """Per-link lognormal latency: ``sample(t, n)[i, j]`` is the round-t
    latency of link (i, j) in units of the nominal round time (median
    ``exp(mu)``).  Symmetric per undirected link; the diagonal is 0."""

    mu: float = 0.0
    sigma: float = 0.25
    seed: int = 0

    def sample(self, t: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _LATENCY_TAG, t)))
        lat = np.exp(self.mu + self.sigma * _symmetric_normal(rng, n))
        np.fill_diagonal(lat, 0.0)
        return lat

    def edge_sample(self, t: int, src, dst) -> np.ndarray:
        """(E,) lognormal latencies for queried edges — its own hash
        stream; ``src == dst`` entries are 0 like the dense diagonal."""
        lo, hi = hashrand.edge_canonical(src, dst)
        z = hashrand.counter_normal(self.seed, _LATENCY_EDGE_TAG, t, lo, hi)
        return np.where(lo == hi, 0.0, np.exp(self.mu + self.sigma * z))
