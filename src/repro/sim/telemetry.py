"""Online mixing telemetry over the *realized* (post-fault) schedule.

A :class:`TelemetryRecorder` plugs into the unified driver loop as (part
of) the ``record`` hook (:func:`repro.core.driver.run_loop` /
``run_algorithm(telemetry=...)`` / ``launch/train.py``) and measures, per
step, what the lossy channel actually did to mixing:

* ``consensus``      — consensus distance ||x - x̄||_F of the stacked
                       iterate (how far the node copies have drifted);
* ``spectral_gap``   — 1 - ||Π_r W^r - 11ᵀ/n||₂ over the trailing window
                       of realized matrices (the empirical multi-round
                       contraction; 0 means the realized window does not
                       mix at all);
* ``eff_diameter``   — empirical effective diameter (paper Definition 2)
                       of the realized window's adjacency, via the
                       vectorized all-pairs frontier propagation in
                       :func:`repro.core.topology.effective_diameter`;
                       ``None``/null when the window never connects;
* ``kinds``          — realized plan-kind counts in the window (``empty``
                       = fully dropped rounds, ``matching`` = surviving
                       (possibly partial) matchings, ...).

``dump(path)`` writes the JSON history together with this field reference.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import gossip, topology as topo

TELEMETRY_FIELDS = {
    "step": "driver step index k",
    "t": "total gossip rounds (budget T) consumed after this step",
    "loss": "runtime loss metric when the step reports one, else null",
    "consensus": "consensus distance ||x - x_bar||_F of the stacked iterate",
    "window": "[lo, hi) realized rounds the windowed metrics below cover",
    "spectral_gap": "1 - ||prod_{r in window} W^r - 11^T/n||_2 (empirical "
                    "multi-round mixing contraction of the realized window)",
    "eff_diameter": "empirical effective diameter (Definition 2) of the "
                    "realized window's adjacency; null when the window "
                    "never connects",
    "kinds": "realized gossip-plan round kinds in the window, counted "
             "(empty = fully dropped rounds)",
    "dense_fallback": "rounds in the window the gossip planner could only "
                      "lower to the generic dense einsum (every structured/"
                      "sparse lowering rejected — see GossipRound."
                      "fallback_reason); 0 for a fully structured window",
    "stale_gap": "delay-adjusted spectral gap: the windowed contraction "
                 "of the rounds whose mixing has actually LANDED on the "
                 "state by this step under stale-window gossip — the "
                 "window shifted back by delay*wps rounds (the last "
                 "delay*wps rounds are still in flight).  Equal to "
                 "spectral_gap at delay=0; only emitted when delay > 0",
    "bytes": "payload bytes transmitted by all active senders over the "
             "rounds this step consumed — the quantized wire format "
             "(repro.core.compress.payload_bytes) once compression is on "
             "and past warmup, full f32 otherwise; dropped rounds and "
             "silent nodes transmit nothing",
    "bytes_total": "cumulative payload bytes since step 0 (accumulated "
                   "every step, including steps the log cadence skips)",
    "sec": "wall-clock seconds this step took",
}


def consensus_distance(x: Any) -> float:
    """||x - x̄||_F over every leaf of a stacked pytree (node axis 0).
    Reduces on device — only one scalar per leaf crosses the host
    boundary, so it is safe to call on full model states."""
    tot = 0.0
    for leaf in jax.tree.leaves(x):
        arr = jnp.asarray(leaf)
        xb = jnp.mean(arr, axis=0, keepdims=True)
        tot += float(jnp.sum((arr - xb) ** 2))
    return tot ** 0.5


def windowed_spectral_gap(mats: np.ndarray) -> float:
    """1 - beta of the window product: the contraction a state actually
    experienced mixing through ``mats`` (R, n, n) in order."""
    P = np.eye(mats.shape[1])
    for W in mats:
        P = W @ P
    return 1.0 - gossip.mixing_beta(P)


def window_adjacency(mats: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """(R, n, n) bool adjacency of a realized matrix window."""
    adj = np.abs(mats) > tol
    adj |= np.eye(mats.shape[1], dtype=bool)[None]
    return adj


def empirical_effective_diameter(adjs: np.ndarray) -> Optional[int]:
    """Definition 2 effective diameter of the realized window, treated as
    one period; ``None`` when some pair never meets within the cap (the
    window does not connect the network)."""
    adjs = np.asarray(adjs, bool)
    R, n = adjs.shape[0], adjs.shape[1]
    if n <= 1:
        return 0
    sched = topo.PeriodicSchedule(tuple(adjs))
    d = topo.effective_diameter(sched, period=R)
    cap = n * R + n + 1
    return None if d > cap else d


class TelemetryRecorder:
    """Collects per-step mixing telemetry from a realized weight schedule.

    ``record(k, t, state, out, dt)`` has exactly the driver's ``record``
    hook signature (``t`` is the budget AFTER the step, so the step just
    consumed rounds [t - wps, t)); use it directly as the hook, chain it
    from an existing one, or pass the recorder as
    ``driver.run_algorithm(..., telemetry=...)``.
    """

    def __init__(self, realized: gossip.WeightSchedule, wps: int,
                 window: int | None = None, every: int = 1,
                 cache: bool = True, compression=None, delay: int = 0):
        self.realized = realized
        self.wps = wps
        self.window = window if window is not None else max(4 * wps, 8)
        self.every = max(1, every)
        # Stale-window gossip (AlgorithmSpec.delay): the mix issued at step
        # k lands on the state applied to the payload from k-delay, so the
        # last delay*wps rounds of the trailing window are "in flight" —
        # ``stale_gap`` measures the contraction of what actually landed.
        self.delay = max(0, int(delay))
        self.history: list = []
        # Bytes accounting: ``compression`` is a
        # repro.core.compress.CompressionConfig (None = full-precision f32
        # payloads); the per-node state dim is read lazily off the first
        # recorded state so the recorder needs no model knowledge.
        self.compression = compression
        self.bytes_total = 0
        self._dim: Optional[int] = None
        # Per-round cache of (W float64, bool adjacency, plan kind): the
        # trailing windows of consecutive records overlap in all but
        # ``wps`` rounds, so materializing/classifying each realized round
        # once makes the per-record conversion cost O(new rounds) instead
        # of O(window).  ``cache=False`` recomputes every round per call
        # (the pre-cache behavior, kept for benchmarking the win).
        self.cache = cache
        self._rounds: dict[int, tuple] = {}

    def _round(self, r: int) -> tuple:
        """(W64, adjacency, kind, dense_fallback) for realized round ``r``:
        ``dense_fallback`` is True when the gossip planner can only lower
        this round to the generic dense einsum (plan_round sets a
        fallback_reason on it)."""
        hit = self._rounds.get(r) if self.cache else None
        if hit is None:
            W = np.asarray(self.realized(r), np.float64)
            adj = np.abs(W) > 1e-12
            adj |= np.eye(W.shape[0], dtype=bool)
            s = self.realized.structure(r)
            kind = s.kind if s is not None else \
                topo.classify_adjacency(adj).kind
            rd = gossip.plan_round(W, s)
            hit = (W, adj, kind, rd.fallback_reason is not None)
            if self.cache:
                self._rounds[r] = hit
        return hit

    def _window_rounds(self, lo: int, t: int):
        """Materialize the window [lo, t): stacked float64 matrices, the
        stacked adjacency, and kind counts.  With the cache on, only the
        rounds that entered the window since the last call convert."""
        floor = lo - self.delay * self.wps  # stale window reaches further back
        if self.cache:  # rounds now behind every window never recur
            for r in [r for r in self._rounds if r < floor]:
                del self._rounds[r]
        rounds = [self._round(r) for r in range(lo, t)]
        mats = np.stack([w for w, _, _, _ in rounds])
        adjs = np.stack([a for _, a, _, _ in rounds])
        kinds: dict = {}
        for _, _, kind, _ in rounds:
            kinds[kind] = kinds.get(kind, 0) + 1
        fallbacks = sum(1 for _, _, _, fb in rounds if fb)
        return mats, adjs, kinds, fallbacks

    def _window_metrics(self, t: int) -> dict:
        lo = max(0, t - self.window)
        if t <= lo:
            return {"window": [lo, t], "spectral_gap": None,
                    "eff_diameter": None, "kinds": {}, "dense_fallback": 0}
        mats, adjs, kinds, fallbacks = self._window_rounds(lo, t)
        out = {"window": [lo, t],
               "spectral_gap": round(windowed_spectral_gap(mats), 6),
               "eff_diameter": empirical_effective_diameter(adjs),
               "kinds": kinds,
               "dense_fallback": fallbacks}
        if self.delay:
            shift = self.delay * self.wps
            s_lo, s_t = max(0, lo - shift), max(0, t - shift)
            if s_t <= s_lo:
                out["stale_gap"] = None  # nothing has landed yet
            else:
                s_mats = np.stack([self._round(r)[0]
                                   for r in range(s_lo, s_t)])
                out["stale_gap"] = round(windowed_spectral_gap(s_mats), 6)
        return out

    def _step_bytes(self, k: int, t: int, state: Any) -> int:
        """Wire bytes the step that just consumed rounds [t - wps, t)
        transmitted: per active sender (a node with at least one realized
        off-diagonal edge that round), the scheme's payload — full f32
        while compression is off or still in warmup."""
        from ..core import compress

        if self._dim is None:
            leaves = jax.tree.leaves(state.x)
            n = leaves[0].shape[0]
            self._dim = sum(int(np.prod(l.shape)) for l in leaves) // n
        c = self.compression
        if c is None or k < c.warmup:
            per = compress.payload_bytes(self._dim, "none")
        else:
            per = compress.payload_bytes(self._dim, c.scheme, c.group)
        total = 0
        for r in range(max(0, t - self.wps), t):
            _, adj, _, _ = self._round(r)
            off = adj & ~np.eye(adj.shape[0], dtype=bool)
            total += int(np.count_nonzero(off.any(axis=1))) * per
        return total

    def record(self, k: int, t: int, state: Any, out: Any,
               dt: float) -> Optional[dict]:
        # bytes accumulate on EVERY step — before the log-cadence gate —
        # so bytes_total stays exact at any ``every``
        step_bytes = self._step_bytes(int(k), int(t), state)
        self.bytes_total += step_bytes
        if k % self.every:
            return None
        loss = None
        if isinstance(out, dict) and "loss" in out:
            loss = float(jax.device_get(out["loss"]))
        entry = {"step": int(k), "t": int(t), "loss": loss,
                 "consensus": consensus_distance(state.x),
                 "bytes": step_bytes, "bytes_total": self.bytes_total,
                 "sec": round(float(dt), 4)}
        entry.update(self._window_metrics(int(t)))
        self.history.append(entry)
        return entry

    def dump(self, path: str) -> None:
        """Write ``{"fields": <reference>, "history": [...]}`` as JSON."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"fields": TELEMETRY_FIELDS, "history": self.history},
                      f, indent=1)
