"""repro.serve — personalized fleet serving.

The trained side of this repo produces a *stacked fleet*: n model copies
with a leading node axis, one per node of the decentralized run.  Under
the ``personalized`` update rule those copies are deliberately distinct
models (loss-proximity neighbor averaging — see
:class:`repro.core.engine.UpdateRule`), and this package closes the
train→serve loop: it serves the whole fleet behind ONE continuously
batched endpoint.

* :mod:`repro.serve.traffic` — synthetic request synthesis and the
  user→node routing policies (``user-affinity`` pins each user to one
  node's personalization via a stable hash; ``round-robin`` cycles the
  fleet — the uniform-fleet ablation);
* :mod:`repro.serve.engine` — the continuous-batching loop
  (admit/route/prefill/decode/evict over a slot-based request table):
  each slot decodes against the *routed node's* parameters, gathered
  from the stacked fleet, with a per-slot KV cache and per-slot decode
  positions.

Entry points: ``repro.exp.run(spec)`` runs the serve phase after
training when ``spec.serve.requests > 0``;
``python -m repro.launch.serve`` is the argv→spec CLI.
"""

from .engine import ServeResult, serve_fleet, shard_fleet  # noqa: F401
from .traffic import Request, route_user, synth_requests  # noqa: F401
