"""Synthetic request traffic and user→node routing for :mod:`repro.serve`.

Requests are synthesized from the :class:`repro.exp.spec.ServeSpec` alone
(seeded, reproducible): a small population of users issues fixed-length
random-token prompts.  Routing decides which fleet node's *personalized*
parameters a request decodes against:

* ``user-affinity`` — each user pins to one node via a stable hash, so a
  user always hits the same personalization (the serving contract that
  makes per-node models meaningful);
* ``round-robin``   — requests cycle the fleet regardless of user (the
  uniform-fleet ablation: only sensible when every model is
  interchangeable).
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One synthetic serve request, already routed."""

    rid: int              # request id (admission order)
    user: int             # issuing user id
    node: int             # routed fleet node (whose params decode this)
    prompt: np.ndarray    # (prompt_len,) int32 token ids


def route_user(user: int, rid: int, fleet: int, policy: str) -> int:
    """Resolve a request's fleet node under ``policy`` (see
    :data:`repro.exp.registry.ROUTING_POLICIES`)."""
    if fleet < 1:
        raise ValueError(f"fleet must be >= 1, got {fleet}")
    if policy == "round-robin":
        return rid % fleet
    if policy == "user-affinity":
        # stable across processes/sessions (unlike hash()): the same user
        # lands on the same node in every run
        return zlib.crc32(str(int(user)).encode()) % fleet
    raise ValueError(f"unknown routing policy {policy!r}")


def synth_requests(serve, *, fleet: int, vocab: int) -> list:
    """Materialize ``serve.requests`` routed requests from a ServeSpec.

    The user population is ~requests/4 (so affinity routing shows repeat
    traffic per user); prompts are uniform random tokens of
    ``serve.prompt_len``.  Deterministic in ``serve.seed``.
    """
    rng = np.random.default_rng(serve.seed)
    users = max(1, serve.requests // 4)
    out = []
    for i in range(serve.requests):
        user = int(rng.integers(users))
        prompt = rng.integers(0, vocab, size=serve.prompt_len,
                              dtype=np.int64).astype(np.int32)
        out.append(Request(rid=i, user=user,
                           node=route_user(user, i, fleet, serve.routing),
                           prompt=prompt))
    return out
