"""Continuous-batching serve engine over a stacked personalized fleet.

One endpoint serves all n per-node models of a decentralized run.  The
engine keeps a fixed table of ``serve.batch`` decode *slots*; every loop
iteration it

1. **admits**  pending requests into free slots — the request's routed
   node decides which fleet member's parameters the slot binds to;
2. **prefills** each admitted prompt into a fresh single-request KV cache
   and scatters it into the slot's row of the stacked cache;
3. **decodes** ONE token for every slot in a single vmapped call: per-slot
   parameters (gathered from the stacked fleet), per-slot cache row, and
   per-slot absolute position — requests at different depths batch
   together, which is the whole point of continuous batching;
4. **evicts**  slots that produced their ``max_new`` tokens, records the
   completed request, and frees the slot for the next admit.

The slot cache is built by stacking ``serve.batch`` independent
single-request caches on a new leading slot axis, so inside the vmap each
slot sees exactly the model's native batch-1 cache — including its OWN
``kpos`` row, which is what lets slots sit at different positions (the
flat serve path shares one position vector across the batch).

On a device mesh the fleet params shard with the training-side rules
(:func:`repro.dist.sharding.param_specs` with ``stacked_nodes`` — the
fleet axis IS the node axis) and the slot cache shards over its slot
axis; off-mesh everything is a no-op.

Decode attention follows the model's kernel policy (``cfg.use_pallas``
routes through :mod:`repro.kernels.ops` with ``interpret="auto"``); the
engine adds no kernel decisions of its own.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import traffic

SERVE_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32}


class ServeResult(NamedTuple):
    """What a serve phase returns.  ``completed`` is one record per
    request (rid/user/node/tokens/latency_ms, admission order);
    ``throughput`` aggregates prefill/decode token rates and request
    latency percentiles — the BENCH_serve row source."""

    completed: list
    throughput: dict
    fleet: int
    serve: Any  # the ServeSpec this ran


def shard_fleet(fleet_params, cfg, mesh):
    """Place a stacked fleet on ``mesh`` with the training-side sharding
    rules: the leading fleet axis is the node axis, everything below it
    follows the per-arch parameter rules."""
    from jax.sharding import NamedSharding

    from ..dist import sharding
    specs = sharding.param_specs(fleet_params, cfg, mesh, stacked_nodes=True)
    return jax.tree.map(
        lambda l, s: jax.device_put(l, NamedSharding(mesh, s)),
        fleet_params, specs)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def serve_fleet(model, fleet_params, serve, *, requests=None, obs=None,
                mesh=None) -> ServeResult:
    """Serve ``requests`` (default: synthesized from ``serve``) against the
    stacked ``fleet_params`` with continuous batching.

    ``model`` is a :class:`repro.models.model.Model`; ``fleet_params``
    leaves carry a leading fleet axis (a trained run's ``state.x``, or a
    slice of it).  ``serve`` is a :class:`repro.exp.spec.ServeSpec`.
    ``obs`` (an :class:`repro.obs.metrics.ObsRecorder` or any sink with
    ``emit``) receives one ``serve_request`` event per completion plus a
    final ``serve_summary``.
    """
    cfg = model.cfg
    if getattr(cfg, "arch_type", "dense") in ("vlm", "audio"):
        raise ValueError("repro.serve serves token-only archs (vlm/audio "
                         "prompts need frontend inputs the synthetic "
                         "traffic cannot provide)")
    if serve.dtype not in SERVE_DTYPES:
        raise ValueError(f"serve.dtype={serve.dtype!r}: unknown "
                         f"(have {sorted(SERVE_DTYPES)})")
    dtype = SERVE_DTYPES[serve.dtype]
    fleet = jax.tree.leaves(fleet_params)[0].shape[0]
    B = serve.batch
    max_len = serve.prompt_len + serve.max_new
    if requests is None:
        requests = traffic.synth_requests(serve, fleet=fleet,
                                          vocab=cfg.vocab_size)

    params = jax.tree.map(lambda l: l.astype(dtype), fleet_params)
    if mesh is not None:
        params = shard_fleet(params, cfg, mesh)

    # Slot cache: B independent single-request caches stacked on a new
    # leading slot axis — each slot owns its kpos row (per-slot positions).
    def one_cache():
        return model.init_cache(1, max_len, dtype)

    cache = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[one_cache() for _ in range(B)])
    if mesh is not None:
        from jax.sharding import NamedSharding

        from ..dist import sharding
        cspecs = sharding.batch_specs(cache, mesh)
        cache = jax.tree.map(
            lambda l, s: jax.device_put(l, NamedSharding(mesh, s)),
            cache, cspecs)

    prefill = jax.jit(lambda p, toks, c: model.prefill(p, {"tokens": toks}, c))

    def _decode_one(p, tok, c, pos):
        logits, c = model.decode_step(p, tok, c, pos)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), c

    vdecode = jax.jit(jax.vmap(_decode_one, in_axes=(0, 0, 0, 0)))
    gather = jax.jit(lambda ids: jax.tree.map(lambda p: p[ids], params))

    # host-side slot table
    active = np.zeros(B, bool)
    node = np.zeros(B, np.int32)
    pos = np.zeros(B, np.int32)
    remaining = np.zeros(B, np.int32)
    rid = np.full(B, -1, np.int64)
    admit_t = np.zeros(B, np.float64)
    toks_out: dict[int, list] = {}
    req_by_id = {r.rid: r for r in requests}

    pending = deque(requests)
    completed: list[dict] = []
    cur_tok = np.zeros((B, 1, 1), np.int32)  # (slot, model batch=1, 1)
    slot_params = None
    params_dirty = True
    prefill_s = decode_s = 0.0
    prefill_toks = decode_toks = 0
    t_start = time.perf_counter()

    while pending or active.any():
        # -- admit + prefill ------------------------------------------------
        for j in np.flatnonzero(~active):
            if not pending:
                break
            req = pending.popleft()
            t0 = time.perf_counter()
            p_node = jax.tree.map(lambda l: l[req.node], params)
            logits, filled = prefill(p_node, jnp.asarray(req.prompt)[None],
                                     one_cache())
            first = int(jnp.argmax(logits[0, -1]))
            cache = jax.tree.map(lambda big, small: big.at[j].set(small),
                                 cache, filled)
            prefill_s += time.perf_counter() - t0
            prefill_toks += serve.prompt_len
            active[j] = True
            node[j] = req.node
            pos[j] = serve.prompt_len
            remaining[j] = serve.max_new - 1
            rid[j] = req.rid
            admit_t[j] = time.perf_counter()
            toks_out[req.rid] = [first]
            cur_tok[j, 0, 0] = first
            params_dirty = True

        if not active.any():
            break

        if params_dirty:
            slot_params = gather(jnp.asarray(node))
            params_dirty = False

        # -- decode one token for every slot (continuous batch) -------------
        t0 = time.perf_counter()
        nxt, cache = vdecode(slot_params, jnp.asarray(cur_tok), cache,
                             jnp.asarray(pos))
        nxt = np.asarray(jax.device_get(nxt)).reshape(B)
        decode_s += time.perf_counter() - t0
        decode_toks += int(active.sum())

        now = time.perf_counter()
        for j in np.flatnonzero(active):
            toks_out[int(rid[j])].append(int(nxt[j]))
            cur_tok[j, 0, 0] = nxt[j]
            pos[j] += 1
            remaining[j] -= 1
            if remaining[j] <= 0:
                # -- evict: record completion, free the slot ----------------
                r = req_by_id[int(rid[j])]
                rec = {"rid": r.rid, "user": r.user, "node": int(node[j]),
                       "tokens": toks_out.pop(r.rid),
                       "latency_ms": round(float(now - admit_t[j]) * 1e3, 3)}
                completed.append(rec)
                if obs is not None:
                    obs.emit({"event": "serve_request", **rec})
                active[j] = False

    wall = time.perf_counter() - t_start
    lat = [c["latency_ms"] for c in completed]
    throughput = {
        "requests": len(completed),
        "fleet": fleet,
        "batch": B,
        "wall_s": round(wall, 4),
        "prefill_tok_s": round(prefill_toks / max(prefill_s, 1e-9), 1),
        "decode_tok_s": round(decode_toks / max(decode_s, 1e-9), 1),
        "requests_per_s": round(len(completed) / max(wall, 1e-9), 2),
        "latency_p50_ms": round(_percentile(lat, 50), 3),
        "latency_p95_ms": round(_percentile(lat, 95), 3),
    }
    if obs is not None:
        obs.emit({"event": "serve_summary", **throughput})
    completed.sort(key=lambda c: c["rid"])
    return ServeResult(completed=completed, throughput=throughput,
                       fleet=fleet, serve=serve)
