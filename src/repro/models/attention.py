"""GQA attention: chunked full-causal, block-local sliding-window (exact,
sub-quadratic), single-token decode against a ring-buffer KV cache, and
bidirectional/cross variants for the encoder-decoder arch.

Shapes: x (B, S, D); q (B, S, KV, G, hd) with G = H // KV; k, v (B, S, KV, hd).
The KV cache stores absolute positions alongside keys so the same masking
logic serves append caches (full attention) and ring buffers (sliding
window): ``mask = (kpos >= 0) & (kpos <= q_pos) & (kpos > q_pos - window)``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers

NEG_INF = -1e30


def init_attention(key, cfg, dtype, q_dim: Optional[int] = None) -> dict:
    D = q_dim or cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers._dense_init(ks[0], (D, H, hd), D, dtype),
        "wk": layers._dense_init(ks[1], (D, KV, hd), D, dtype),
        "wv": layers._dense_init(ks[2], (D, KV, hd), D, dtype),
        "wo": layers._dense_init(ks[3], (H, hd, D), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def project_q(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    B, S, H, hd = q.shape
    KV = cfg.num_kv_heads
    return q.reshape(B, S, KV, H // KV, hd)


def project_kv(p, x):
    k = jnp.einsum("bsd,djk->bsjk", x, p["wk"])
    v = jnp.einsum("bsd,djk->bsjk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def out_proj(p, o, cfg):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _softcap(s, cap):
    return cap * jnp.tanh(s / cap) if cap else s


def _sdpa(q, k, v, mask, scale, softcap):
    """q (B,Sq,J,G,hd); k,v (B,Sk,J,hd); mask broadcastable to (B,J,G,Sq,Sk)."""
    s = jnp.einsum("bqjgh,bkjh->bjgqk", q, k).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bjgqk,bkjh->bqjgh", p.astype(v.dtype), v)
    return o


def attend_full(q, k, v, q_pos, k_pos, *, causal=True, window=0, softcap=0.0,
                q_chunk=1024):
    """Chunked-over-queries attention; peak activation O(Sq_chunk * Sk)."""
    B, Sq, J, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    if Sq <= q_chunk:
        mask = _pos_mask(q_pos, k_pos, causal, window)
        o = _sdpa(q, k, v, mask, scale, softcap)
        return o.reshape(B, Sq, J * G, hd)

    pad = (-Sq) % q_chunk
    if pad:  # pad queries (masked rows are sliced away below)
        q = jnp.pad(q, [(0, 0), (0, pad)] + [(0, 0)] * 3)
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
    Sp = q.shape[1]
    nc = Sp // q_chunk
    qs = q.reshape(B, nc, q_chunk, J, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nc, q_chunk)

    @jax.checkpoint
    def one(args):
        qc, qpc = args
        mask = _pos_mask(qpc, k_pos, causal, window)
        return _sdpa(qc, k, v, mask, scale, softcap)

    o = jax.lax.map(one, (qs, qp))
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, J * G, hd)
    return o[:, :Sq]


def _pos_mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m[None, None, None]  # (1,1,1,Sq,Sk)


def attend_sliding_block(q, k, v, q_pos, *, window, softcap=0.0):
    """Exact sliding-window causal attention in O(S * 2w): queries in blocks
    of w attend to their own and the previous key block."""
    B, S, J, G, hd = q.shape
    w = window
    scale = 1.0 / math.sqrt(hd)
    pad = (-S) % w
    if pad:
        padc = [(0, 0), (0, pad)] + [(0, 0)] * 3
        q = jnp.pad(q, padc)
        k = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-10 * w)
    Sp = q.shape[1]
    nb = Sp // w
    qb = q.reshape(B, nb, w, J, G, hd)
    kb = k.reshape(B, nb, w, J, hd)
    vb = v.reshape(B, nb, w, J, hd)
    # previous key block (block -1 = zeros, masked out by position)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2w, J, hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    qp = q_pos.reshape(nb, w)
    # key positions come from the *block structure* (padded rows marked -1),
    # not from qp - w, which breaks when the final block is padding
    kpos = jnp.where(jnp.arange(Sp) < S, jnp.arange(Sp), -1)
    kpb = kpos.reshape(nb, w)
    kp_prev = jnp.concatenate([jnp.full((1, w), -1, kpb.dtype), kpb[:-1]],
                              axis=0)
    kp = jnp.concatenate([kp_prev, kpb], axis=1)  # (nb, 2w)
    mask = (kp[:, None, :] <= qp[:, :, None]) & (kp[:, None, :] > qp[:, :, None] - w)
    mask &= kp[:, None, :] >= 0
    mask = mask[None, :, None, None]  # (1, nb, 1, 1, w, 2w)
    s = jnp.einsum("bnqjgh,bnkjh->bnjgqk", qb, k2).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnjgqk,bnkjh->bnqjgh", p.astype(v2.dtype), v2)
    o = o.reshape(B, Sp, J * G, hd)
    return o[:, :S]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    """Cache for one attention layer.  ``max_len`` = window size for
    sliding-window layers (ring buffer), else the full context length."""
    C = min(cfg.window, max_len) if cfg.window else max_len
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, C, KV, hd), dtype),
        "v": jnp.zeros((batch, C, KV, hd), dtype),
        "kpos": jnp.full((C,), -1, jnp.int32),
    }


def cache_insert(cache: dict, k1, v1, pos) -> dict:
    """Insert a single-token k/v at absolute position ``pos`` (ring)."""
    C = cache["k"].shape[1]
    slot = pos % C
    k = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(cache["kpos"], pos[None].astype(jnp.int32), (slot,))
    return {"k": k, "v": v, "kpos": kpos}


def cache_prefill(cache: dict, k, v, positions) -> dict:
    """Write a full prefill's k/v into the cache (keeps the last C tokens)."""
    C = cache["k"].shape[1]
    S = k.shape[1]
    if S >= C:
        ks, vs, ps = k[:, -C:], v[:, -C:], positions[-C:]
        slots = ps % C
        knew = cache["k"].at[:, slots].set(ks)
        vnew = cache["v"].at[:, slots].set(vs)
        pnew = cache["kpos"].at[slots].set(ps.astype(jnp.int32))
    else:
        slots = positions % C
        knew = cache["k"].at[:, slots].set(k)
        vnew = cache["v"].at[:, slots].set(v)
        pnew = cache["kpos"].at[slots].set(positions.astype(jnp.int32))
    return {"k": knew, "v": vnew, "kpos": pnew}


def decode_attend(q1, cache: dict, pos, *, window=0, softcap=0.0):
    """q1 (B, 1, J, G, hd) against the cache; returns (B, 1, H, hd)-flat."""
    B, _, J, G, hd = q1.shape
    scale = 1.0 / math.sqrt(hd)
    kpos = cache["kpos"]
    mask = (kpos >= 0) & (kpos <= pos)
    if window:
        mask &= kpos > pos - window
    s = jnp.einsum("bqjgh,bkjh->bjgqk", q1, cache["k"]).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bjgqk,bkjh->bqjgh", p.astype(cache["v"].dtype), cache["v"])
    return o.reshape(B, 1, J * G, hd)
