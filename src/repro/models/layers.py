"""Shared layer primitives: norms, MLPs, embeddings, RoPE.

Everything is functional: ``init_*`` builds a params dict, ``apply``-style
functions are pure.  Param leaf names are load-bearing — the sharding rules
in :mod:`repro.dist.sharding` match on them.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": _dense_init(ks[0], (d_model, d_ff), d_model, dtype),
         "wo": _dense_init(ks[1], (d_ff, d_model), d_ff, dtype)}
    if act in ("swiglu", "geglu"):
        p["wg"] = _dense_init(ks[2], (d_model, d_ff), d_model, dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.gelu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":  # squared ReLU (nemotron-4)
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown activation {act!r}")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    ks = jax.random.split(key, 2)
    p = {"embedding": _dense_init(ks[0], (vocab, d_model), d_model, dtype)}
    if not tie:
        p["unembed"] = _dense_init(ks[1], (d_model, vocab), d_model, dtype)
    return p


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    if "unembed" in p:
        return jnp.einsum("...d,dv->...v", x, p["unembed"])
    return jnp.einsum("...d,vd->...v", x, p["embedding"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (..., S) int -> cos, sin of shape (..., S, head_dim // 2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    half = d_model // 2
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    ang = pos * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
