"""Mixture-of-Experts layer: top-k routing with capacity-based einsum
dispatch (Mesh-TensorFlow / MaxText style — TPU-friendly: no dynamic
shapes, experts shardable over the "model"/expert axis).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers


def _padded_experts(cfg) -> int:
    return getattr(cfg, "moe_pad_experts", 0) or cfg.num_experts


def init_moe(key, cfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    E = _padded_experts(cfg)  # pad experts so E divides the model axis
    ks = jax.random.split(key, 5)
    p = {
        "router": layers._dense_init(ks[0], (D, E), D, dtype),
        "wi": layers._dense_init(ks[1], (E, D, F), D, dtype),
        "wg": layers._dense_init(ks[2], (E, D, F), D, dtype),
        "wo": layers._dense_init(ks[3], (E, F, D), F, dtype),
    }
    if cfg.shared_expert:
        p["shared"] = layers.init_mlp(ks[4], D, F, "swiglu", dtype)
    return p


def _capacity(tokens: int, k: int, num_experts: int, factor: float = 1.25) -> int:
    return max(4, int(math.ceil(tokens * k * factor / num_experts)))


def apply_moe(p: dict, x: jax.Array, cfg, capacity_factor: float | None = None):
    """x: (B, S, D) -> (out, aux_loss).  Dropped tokens (over capacity) fall
    back to the residual stream (output 0 for the MoE branch).

    cfg.moe_seq_group > 0 splits the token stream into groups of that many
    tokens and dispatches each group independently (vmap) — the dispatch /
    combine one-hots then scale with group size instead of B*S, which is
    the difference between O((BS)^2 k / E) and O(BS * g * k / E) dispatch
    memory at 32k-token prefill."""
    group = getattr(cfg, "moe_seq_group", 0)
    B, S, D = x.shape
    T_all = B * S
    if group and T_all > group and T_all % group == 0:
        xg = x.reshape(T_all // group, 1, group, D)
        out, aux = jax.vmap(lambda xx: _moe_dense(p, xx, cfg, capacity_factor))(xg)
        return out.reshape(B, S, D), jnp.mean(aux)
    return _moe_dense(p, x, cfg, capacity_factor)


def _moe_dense(p: dict, x: jax.Array, cfg, capacity_factor: float | None = None):
    B, S, D = x.shape
    E, k = _padded_experts(cfg), cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    if E > cfg.num_experts:  # never route to padding experts
        pad = jnp.full((T, E - cfg.num_experts), -1e30, logits.dtype)
        logits = jnp.concatenate([logits[:, :cfg.num_experts], pad], axis=-1)
    gates = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    topv, topi = jax.lax.top_k(gates, k)                          # (T, k)
    # renormalize the chosen gates
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    cf = capacity_factor if capacity_factor is not None else getattr(cfg, 'moe_capacity_factor', 1.25)
    C = _capacity(T, k, E, cf)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)             # (T, k, E)
    flat = onehot.reshape(T * k, E)
    # position of each (token, choice) within its expert's capacity buffer
    pos = jnp.cumsum(flat, axis=0) - flat                         # (T*k, E)
    pos = (pos * flat).sum(-1).reshape(T, k)                      # (T, k)
    expert = topi                                                 # (T, k)
    keep = pos < C

    de = jax.nn.one_hot(expert, E, dtype=xf.dtype)                # (T, k, E)
    dc = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=xf.dtype)  # drops -> off-buffer
    dispatch = jnp.einsum("tke,tkc->tec", de, dc)                 # (T, E, C)
    combine = jnp.einsum("tke,tkc,tk->tec", de, dc, topv.astype(xf.dtype))

    xin = jnp.einsum("tec,td->ecd", dispatch, xf)                 # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"])
    h = jax.nn.silu(g) * h
    xout = jnp.einsum("ecf,efd->ecd", h, p["wo"])                 # (E, C, D)
    out = jnp.einsum("tec,ecd->td", combine, xout)

    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], xf, "swiglu")

    # Switch-style load-balance loss
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    router_prob = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(density * router_prob)
    return out.reshape(B, S, D), aux
