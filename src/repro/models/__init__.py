"""Model substrate: layers, attention, MoE, SSM, RG-LRU, transformer and
encoder-decoder assemblies, and the unified build API."""

from . import attention, encdec, layers, model, moe, rglru, ssm, transformer  # noqa: F401
from .model import Model, build, decode_templates, materialize_batch, train_batch_template  # noqa: F401
