"""Mamba-1 selective SSM block (falcon-mamba-7b family, arXiv:2312.00752 /
2410.05355).

The sequence mixer is the diagonal linear recurrence
``h_t = a_t * h_{t-1} + b_t`` with input-dependent (selective) a, b.  The
recurrence is evaluated with :func:`chunked_linear_scan` — sequential over
chunks, parallel (associative scan) inside a chunk — which bounds the
materialized state tensor to (B, chunk, d_inner, N) and mirrors exactly what
the Pallas ``linear_recurrence`` kernel does in VMEM on TPU.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers


# ---------------------------------------------------------------------------
# Chunked diagonal linear recurrence (shared by mamba and RG-LRU)
# ---------------------------------------------------------------------------

def chunked_linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None,
                        chunk: int = 64, use_pallas: bool = False):
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: (B, S, ...); h0: (B, ...) initial state (zeros if None).
    Returns (h_all (B, S, ...), h_last (B, ...)).

    use_pallas routes through the linear_recurrence kernel (compiled on
    TPU, interpret mode elsewhere — resolve_interpret's "auto" policy); non-zero h0 is folded into b_0 (b_0 += a_0 * h0).
    """
    B, S = a.shape[:2]
    rest = a.shape[2:]
    if h0 is None:
        h0 = jnp.zeros((B,) + rest, a.dtype)
    if use_pallas and S > 1:
        from ..kernels.linear_recurrence import linear_recurrence as _lr
        C = 1
        for r in rest:
            C *= r
        af = a.reshape(B, S, C).astype(jnp.float32)
        bf = b.reshape(B, S, C).astype(jnp.float32)
        bf = bf.at[:, 0].add(af[:, 0] * h0.reshape(B, C).astype(jnp.float32))
        bt = min(128, S)
        if S % bt == 0 and C % min(512, C) == 0:
            h_all, h_last = _lr(af, bf, block_t=bt, block_c=min(512, C))
            return (h_all.reshape((B, S) + rest),
                    h_last.reshape((B,) + rest))
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * len(rest),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * len(rest))
    nc = a.shape[1] // c
    a_ = a.reshape((B, nc, c) + rest).swapaxes(0, 1)  # (nc, B, c, ...)
    b_ = b.reshape((B, nc, c) + rest).swapaxes(0, 1)

    def combine(x, y):
        (a1, b1), (a2, b2) = x, y
        return a1 * a2, a2 * b1 + b2

    def step(h, ab):
        ac, bc = ab
        # within-chunk prefix: cumulative (A, Bc) pairs
        A, Bc = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = A * h[:, None] + Bc                     # (B, c, ...)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(step, h0, (a_, b_))
    h_all = h_chunks.swapaxes(0, 1).reshape((B, nc * c) + rest)
    return h_all[:, :S], h_last


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None):
    """Depthwise causal conv.  x: (B, S, C); w: (width, C); state: (B, width-1, C)
    holds trailing inputs from the previous segment.  Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return y + b, new_state


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype) -> dict:
    D, di, N, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    w = cfg.conv_width
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    dt_bias = jnp.log(jnp.expm1(
        jnp.clip(jnp.exp(jax.random.uniform(ks[5], (di,))
                         * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)),
                 1e-4)))
    return {
        "in_proj": layers._dense_init(ks[0], (D, 2 * di), D, dtype),
        "conv_w": layers._dense_init(ks[1], (w, di), w, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers._dense_init(ks[2], (di, dr + 2 * N), di, dtype),
        "dt_proj": layers._dense_init(ks[3], (dr, di), dr, dtype),
        "dt_bias": dt_bias.astype(dtype),
        "A_log": jnp.log(A).astype(jnp.float32),
        "Dskip": jnp.ones((di,), dtype),
        "out_proj": layers._dense_init(ks[4], (di, D), di, dtype),
    }


def _selective_terms(p, xc, cfg):
    """From post-conv activations xc (B, S, di) build recurrence terms."""
    N, dr = cfg.ssm_state, cfg.dt_rank
    dbc = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"])
    dt_low, Bmat, Cmat = jnp.split(dbc, [dr, dr + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"])
        + p["dt_bias"].astype(jnp.float32))                     # (B,S,di)
    A = -jnp.exp(p["A_log"])                                     # (di, N)
    a = jnp.exp(dt[..., None] * A)                               # (B,S,di,N)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bmat[:, :, None, :].astype(jnp.float32)
    return a, b, Cmat


def mamba_forward(p, x, cfg, *, state=None, chunk: int = 64):
    """x: (B, S, D) -> (y (B, S, D), new_state).  ``state`` is the serve-time
    cache {'conv': (B, w-1, di), 'h': (B, di, N)} or None for training."""
    B, S, D = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xr, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state else None
    xc, new_conv = causal_conv1d(xr, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    a, b, Cmat = _selective_terms(p, xc, cfg)
    h0 = state["h"] if state else None
    h_all, h_last = chunked_linear_scan(a, b, h0, chunk=chunk,
                                        use_pallas=cfg.use_pallas)
    y = jnp.einsum("bsdn,bsn->bsd", h_all,
                   Cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + p["Dskip"] * xc
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    new_state = {"conv": new_conv, "h": h_last}
    return out, new_state


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
