"""Encoder-decoder transformer (whisper-tiny backbone, arXiv:2212.04356).

The mel-spectrogram + conv2 frontend is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings (B, Se, D).  The
encoder is bidirectional; the decoder is causal with cross-attention whose
k/v are computed once at prefill and cached.  Whisper uses layernorm +
GELU + absolute positions (we use the sinusoidal table for both stacks —
the learned 448-token table does not extend to the artificial 32k/500k
decode shapes; noted in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import layers


def _init_xattn(key, cfg, dtype):
    return attn.init_attention(key, cfg, dtype)


def init_encoder_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": layers.init_norm(cfg, dtype),
            "attn": attn.init_attention(ks[0], cfg, dtype),
            "ln2": layers.init_norm(cfg, dtype),
            "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype)}


def init_decoder_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": layers.init_norm(cfg, dtype),
            "self": attn.init_attention(ks[0], cfg, dtype),
            "ln_x": layers.init_norm(cfg, dtype),
            "cross": _init_xattn(ks[1], cfg, dtype),
            "ln2": layers.init_norm(cfg, dtype),
            "mlp": layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype)}


def init_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    kE, kD, ke, kf1, kf2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(kE, cfg.encoder_layers)
    dec_keys = jax.random.split(kD, cfg.num_layers)
    return {
        "embed": layers.init_embed(ke, cfg.vocab_size, cfg.d_model, dtype,
                                   cfg.tie_embeddings),
        "enc": jax.vmap(lambda k: init_encoder_layer(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: init_decoder_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": layers.init_norm(cfg, dtype),
        "final_norm": layers.init_norm(cfg, dtype),
    }


def _attend(p, cfg, x, kv_x, *, causal, window=0):
    q = attn.project_q(p, x, cfg)
    k, v = attn.project_kv(p, kv_x)
    Sq, Sk = x.shape[1], kv_x.shape[1]
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    if causal and window and Sq > window:
        o = attn.attend_sliding_block(q, k, v, q_pos, window=window)
    else:
        o = attn.attend_full(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, q_chunk=cfg.q_chunk)
    return attn.out_proj(p, o, cfg)


def encode(params, cfg, frames):
    """frames: (B, Se, D) stub embeddings -> (B, Se, D) encoder states."""
    x = frames + layers.sinusoidal_positions(frames.shape[1], cfg.d_model
                                             ).astype(frames.dtype)[None]

    def layer_fn(xc, lp):
        h = layers.apply_norm(lp["ln1"], xc)
        xc = xc + _attend(lp["attn"], cfg, h, h, causal=False)
        h = layers.apply_norm(lp["ln2"], xc)
        xc = xc + layers.apply_mlp(lp["mlp"], h, "gelu")
        return xc, None

    if cfg.unroll:
        nl = jax.tree.leaves(params["enc"])[0].shape[0]
        for u in range(nl):
            x, _ = layer_fn(x, jax.tree.map(lambda t: t[u], params["enc"]))
    else:
        x, _ = jax.lax.scan(layer_fn, x, params["enc"])
    return layers.apply_norm(params["enc_norm"], x)


def _decoder_stack(params, cfg, x, enc_out, mode, cache, pos):
    """mode: 'train'|'prefill'|'decode'. cache: stacked per-layer dicts."""
    def layer_fn(carry, xs):
        xc = carry
        lp, lc = xs
        new_c = {}
        h = layers.apply_norm(lp["ln1"], xc)
        if mode == "decode":
            q = attn.project_q(lp["self"], h, cfg)
            k1, v1 = attn.project_kv(lp["self"], h)
            cnew = attn.cache_insert(lc["self"], k1, v1, pos)
            o = attn.decode_attend(q, cnew, pos, window=cfg.window)
            xc = xc + attn.out_proj(lp["self"], o, cfg)
            new_c["self"] = cnew
            # cross-attention against cached encoder k/v
            hq = layers.apply_norm(lp["ln_x"], xc)
            qx = attn.project_q(lp["cross"], hq, cfg)
            kp = lc["cross_kpos"]
            ox = attn.decode_attend(
                qx, {"k": lc["cross_k"], "v": lc["cross_v"], "kpos": kp},
                jnp.int32(2 ** 30))
            xc = xc + attn.out_proj(lp["cross"], ox, cfg)
            new_c.update(cross_k=lc["cross_k"], cross_v=lc["cross_v"],
                         cross_kpos=kp)
        else:
            xc = xc + _attend(lp["self"], cfg, h, h, causal=True,
                              window=cfg.window)
            hq = layers.apply_norm(lp["ln_x"], xc)
            xc = xc + _attend(lp["cross"], cfg, hq, enc_out, causal=False)
            if mode == "prefill":
                S = h.shape[1]
                k, v = attn.project_kv(lp["self"], h)
                new_c["self"] = attn.cache_prefill(lc["self"], k, v,
                                                   jnp.arange(S))
                kx, vx = attn.project_kv(lp["cross"], enc_out)
                new_c.update(cross_k=kx, cross_v=vx,
                             cross_kpos=jnp.arange(enc_out.shape[1], dtype=jnp.int32))
        h = layers.apply_norm(lp["ln2"], xc)
        xc = xc + layers.apply_mlp(lp["mlp"], h, "gelu")
        if not new_c:
            new_c = lc
        return xc, new_c

    if cfg.unroll:
        nl = jax.tree.leaves(params["dec"])[0].shape[0]
        outs = []
        for u in range(nl):
            lp = jax.tree.map(lambda t: t[u], params["dec"])
            lc = (jax.tree.map(lambda t: t[u], cache) if cache is not None
                  else {"self": None})
            x, yc = layer_fn(x, (lp, lc))
            outs.append(yc)
        if cache is None:
            return x, None
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    if cache is None:
        xout, _ = jax.lax.scan(lambda c, lp: layer_fn(c, (lp, {"self": None})),
                               x, params["dec"])
        return xout, None
    xout, new_cache = jax.lax.scan(layer_fn, x, (params["dec"], cache))
    return xout, new_cache


def forward(params, cfg, tokens, frames, *, mode="train", cache=None, pos=None):
    """tokens: (B, S); frames: (B, Se, D) or None when decoding from cache."""
    x = layers.embed_tokens(params["embed"], tokens)
    if mode == "decode":
        # absolute sinusoidal position for `pos`
        posv = jnp.asarray(pos, jnp.float32)
        half = cfg.d_model // 2
        import math as _m
        freq = jnp.exp(-_m.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                       / max(1, half - 1))
        ang = posv * freq
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pe.astype(x.dtype)
        enc_out = None
    else:
        x = x + layers.sinusoidal_positions(tokens.shape[1], cfg.d_model
                                            ).astype(x.dtype)[None]
        enc_out = encode(params, cfg, frames)
    x, new_cache = _decoder_stack(params, cfg, x, enc_out, mode, cache, pos)
    x = layers.apply_norm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x)
    return logits, jnp.zeros((), jnp.float32), new_cache


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    nl, Se = cfg.num_layers, cfg.encoder_seq
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    def one():
        return {"self": attn.init_cache(cfg, batch, max_len, dtype),
                "cross_k": jnp.zeros((batch, Se, KV, hd), dtype),
                "cross_v": jnp.zeros((batch, Se, KV, hd), dtype),
                "cross_kpos": jnp.arange(Se, dtype=jnp.int32)}
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(nl)])


def train_loss(params, cfg, batch, aux_weight: float = 0.0):
    tokens, frames = batch["tokens"], batch["frames"]
    logits, _, _ = forward(params, cfg, tokens, frames, mode="train")
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def prefill(params, cfg, tokens, frames, cache):
    logits, _, cache = forward(params, cfg, tokens, frames, mode="prefill",
                               cache=cache)
    return logits[:, -1:], cache


def decode_step(params, cfg, token, cache, pos):
    logits, _, cache = forward(params, cfg, token, None, mode="decode",
                               cache=cache, pos=pos)
    return logits, cache
