"""Unified model API: ``build(cfg)`` returns pure functions shared by the
trainer, the server, the smoke tests and the dry-run lowering.

``input_template`` produces jax.ShapeDtypeStruct stand-ins for every model
input of a given (config x input-shape) pair — the dry-run lowers against
these without allocating anything.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import encdec, transformer


class Model(NamedTuple):
    cfg: Any
    init: Callable            # (key) -> params
    train_loss: Callable      # (params, batch) -> scalar
    prefill: Callable         # (params, batch, cache) -> (logits, cache)
    decode_step: Callable     # (params, token, cache, pos) -> (logits, cache)
    init_cache: Callable      # (batch, max_len, dtype) -> cache


def build(cfg) -> Model:
    if cfg.arch_type == "audio":
        return Model(
            cfg=cfg,
            init=lambda key, dtype=None: encdec.init_params(key, cfg, dtype),
            train_loss=lambda p, b: encdec.train_loss(p, cfg, b),
            prefill=lambda p, b, c: encdec.prefill(p, cfg, b["tokens"],
                                                   b["frames"], c),
            decode_step=lambda p, t, c, pos: encdec.decode_step(p, cfg, t, c, pos),
            init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
                encdec.init_cache(cfg, batch, max_len, dtype),
        )

    def _prefill(p, b, c):
        return transformer.prefill(p, cfg, b["tokens"], c,
                                   prefix_embeds=b.get("prefix_embeds"),
                                   last_only=cfg.prefill_last_only)

    return Model(
        cfg=cfg,
        init=lambda key, dtype=None: transformer.init_params(key, cfg, dtype),
        train_loss=lambda p, b: transformer.train_loss(p, cfg, b),
        prefill=_prefill,
        decode_step=lambda p, t, c, pos: transformer.decode_step(p, cfg, t, c, pos),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            transformer.init_cache(cfg, batch, max_len, dtype),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_template(cfg, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
    """Per-node training batch.  For vlm/audio, part of the sequence budget
    is the stub frontend embedding."""
    if cfg.arch_type == "vlm":
        P = cfg.frontend_tokens
        return {"tokens": _sds((batch, seq - P), jnp.int32),
                "prefix_embeds": _sds((batch, P, cfg.d_model), dtype)}
    if cfg.arch_type == "audio":
        return {"tokens": _sds((batch, seq), jnp.int32),
                "frames": _sds((batch, cfg.encoder_seq, cfg.d_model), dtype)}
    return {"tokens": _sds((batch, seq), jnp.int32)}


def decode_templates(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """(token, cache, pos) templates for serve_step with a seq-long context."""
    token = _sds((batch, 1), jnp.int32)
    model = build(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(batch, seq, dtype))
    pos = _sds((), jnp.int32)
    return token, cache, pos


def materialize_batch(cfg, batch: int, seq: int, key, dtype=jnp.bfloat16) -> dict:
    """A real (random) training batch matching ``train_batch_template``."""
    tmpl = train_batch_template(cfg, batch, seq, dtype)
    out = {}
    for name, spec in tmpl.items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, spec.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, spec.shape, spec.dtype) * 0.02
    return out
