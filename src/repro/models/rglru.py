"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal mixing block: dual linear branches (recurrent branch with causal
conv + RG-LRU gated diagonal recurrence; gate branch with GeLU), merged
multiplicatively and projected back to d_model.  Shares
:func:`repro.models.ssm.chunked_linear_scan` with mamba — both are diagonal
linear recurrences, which is why one Pallas kernel serves both.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .ssm import causal_conv1d, chunked_linear_scan

_C_EXP = 8.0  # Griffin's fixed exponent scale


def init_rglru(key, cfg, dtype) -> dict:
    D, R = cfg.d_model, cfg.lru_width
    w = cfg.conv_width
    ks = jax.random.split(key, 7)
    # Lambda init: a = sigmoid(lam) in [0.9, 0.999] per Griffin
    u = jax.random.uniform(ks[5], (R,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "wx": layers._dense_init(ks[0], (D, R), D, dtype),       # recurrent branch
        "wy": layers._dense_init(ks[1], (D, R), D, dtype),       # gate branch
        "conv_w": layers._dense_init(ks[2], (w, R), w, dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "w_rgate": layers._dense_init(ks[3], (R, R), R, dtype),  # recurrence gate
        "w_igate": layers._dense_init(ks[4], (R, R), R, dtype),  # input gate
        "b_rgate": jnp.zeros((R,), dtype),
        "b_igate": jnp.zeros((R,), dtype),
        "lam": lam.astype(jnp.float32),
        "wo": layers._dense_init(ks[6], (R, D), R, dtype),
    }


def rglru_forward(p, x, cfg, *, state=None, chunk: int = 64):
    """x: (B, S, D) -> (y, new_state); state = {'conv': (B,w-1,R), 'h': (B,R)}."""
    xb = jnp.einsum("bsd,dr->bsr", x, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["wy"]))
    conv_state = state["conv"] if state else None
    xc, new_conv = causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p["w_rgate"])
                       + p["b_rgate"]).astype(jnp.float32)
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p["w_igate"])
                       + p["b_igate"]).astype(jnp.float32)
    log_a_base = -jax.nn.softplus(-p["lam"])          # log sigmoid(lam) <= 0
    log_a = _C_EXP * r * log_a_base[None, None, :]
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = state["h"] if state else None
    h_all, h_last = chunked_linear_scan(a, b, h0, chunk=chunk,
                                        use_pallas=cfg.use_pallas)
    y = (h_all.astype(x.dtype) * yb)
    out = jnp.einsum("bsr,rd->bsd", y, p["wo"])
    return out, {"conv": new_conv, "h": h_last}


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
