"""Decoder-only transformer assembly for all decoder-style arch families
(dense / moe / ssm / hybrid / vlm-backbone).

Layers are grouped into *pattern units* (cfg.pattern, e.g. ("rglru",
"rglru", "attn") for recurrentgemma); the forward pass is a ``lax.scan``
over stacked unit params so the HLO stays O(pattern) instead of O(layers).
Remainder layers (num_layers % len(pattern)) form a second, shorter stack.

Three entry points:
  * ``forward``       — full-sequence training/prefill forward to logits
  * ``prefill``       — forward + populate a serve cache
  * ``decode_step``   — one token against the cache (serve_step)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import layers, moe, rglru, ssm


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_one_layer(key, cfg, kind, dtype):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        return {"ln1": layers.init_norm(cfg, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "ln2": layers.init_norm(cfg, dtype),
                "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}
    if kind == "moe":
        return {"ln1": layers.init_norm(cfg, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "ln2": layers.init_norm(cfg, dtype),
                "moe": moe.init_moe(ks[1], cfg, dtype)}
    if kind == "mamba":
        return {"ln1": layers.init_norm(cfg, dtype),
                "mamba": ssm.init_mamba(ks[0], cfg, dtype)}
    if kind == "rglru":
        return {"ln1": layers.init_norm(cfg, dtype),
                "rec": rglru.init_rglru(ks[0], cfg, dtype),
                "ln2": layers.init_norm(cfg, dtype),
                "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}
    raise ValueError(f"unknown layer kind {kind!r}")


def _init_unit(key, cfg, pattern, dtype):
    ks = jax.random.split(key, len(pattern))
    return {f"{i}_{kind}": _init_one_layer(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(pattern)}


def _apply_attn_layer(p, x, cfg, rope, mode, cache, pos):
    """mode: 'train' (no cache), 'prefill', 'decode'."""
    h = layers.apply_norm(p["ln1"], x)
    q = attn.project_q(p["attn"], h, cfg)
    if mode == "decode":
        cos, sin = rope
        k1, v1 = attn.project_kv(p["attn"], h)
        k1 = layers.apply_rope(k1, cos, sin)
        qf = q.reshape(q.shape[:2] + (cfg.num_heads, cfg.head_dim))
        qf = layers.apply_rope(qf, cos, sin)
        q = qf.reshape(q.shape)
        cache_new = attn.cache_insert(cache, k1, v1, pos)
        if cfg.use_pallas:
            # route through the kernel policy layer (not the raw kernel):
            # ops picks interpret mode per backend and keeps one jit cache
            from ..kernels import ops as kops
            o = kops.decode_attention(
                q, cache_new["k"], cache_new["v"], cache_new["kpos"], pos,
                window=cfg.window, use_pallas=True, interpret="auto")
        else:
            o = attn.decode_attend(q, cache_new, pos, window=cfg.window,
                                   softcap=cfg.logit_softcap)
    else:
        k, v = attn.project_kv(p["attn"], h)
        cos, sin = rope
        B, S = h.shape[:2]
        qf = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
        qf = layers.apply_rope(qf, cos, sin)
        q = qf.reshape(B, S, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads,
                       cfg.head_dim)
        k = layers.apply_rope(k, cos, sin)
        q_pos = jnp.arange(S)
        if cfg.use_pallas:
            from ..kernels.flash_attention import flash_attention as _fl
            of = _fl(qf, k, v, causal=True, window=cfg.window,
                     block_q=min(128, S), block_k=min(128, S))
            o = of  # (B, S, H, hd) == flat layout expected below
        elif cfg.window and S > cfg.window:
            o = attn.attend_sliding_block(q, k, v, q_pos, window=cfg.window,
                                          softcap=cfg.logit_softcap)
        else:
            o = attn.attend_full(q, k, v, q_pos, q_pos, causal=True,
                                 window=cfg.window, softcap=cfg.logit_softcap,
                                 q_chunk=cfg.q_chunk)
        if mode == "prefill":
            cache_new = attn.cache_prefill(cache, k, v, q_pos)
        else:
            cache_new = cache
    x = x + attn.out_proj(p["attn"], o, cfg)
    return x, cache_new


def _apply_layer(p, x, cfg, kind, rope, mode, cache, pos):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe"):
        x, cache = _apply_attn_layer(p, x, cfg, rope, mode, cache, pos)
        h = layers.apply_norm(p["ln2"], x)
        if kind == "attn":
            x = x + layers.apply_mlp(p["mlp"], h, cfg.mlp_act)
        else:
            y, aux = moe.apply_moe(p["moe"], h, cfg)
            x = x + y
        return x, cache, aux
    if kind == "mamba":
        h = layers.apply_norm(p["ln1"], x)
        y, new_state = ssm.mamba_forward(
            p["mamba"], h, cfg, state=cache if mode != "train" else None,
            chunk=cfg.scan_chunk)
        return x + y, (new_state if mode != "train" else cache), aux
    if kind == "rglru":
        h = layers.apply_norm(p["ln1"], x)
        y, new_state = rglru.rglru_forward(
            p["rec"], h, cfg, state=cache if mode != "train" else None,
            chunk=cfg.scan_chunk)
        x = x + y
        h = layers.apply_norm(p["ln2"], x)
        x = x + layers.apply_mlp(p["mlp"], h, cfg.mlp_act)
        return x, (new_state if mode != "train" else cache), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Cache structure
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, kind, batch, max_len, dtype):
    if kind in ("attn", "moe"):
        return attn.init_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return ssm.init_mamba_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    units, rem = cfg.units_and_rem
    def unit_cache():
        return {f"{i}_{kind}": _init_layer_cache(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(cfg.pattern)}
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[unit_cache() for _ in range(units)]) if units else {}
    remc = {f"{i}_{kind}": _init_layer_cache(cfg, kind, batch, max_len, dtype)
            for i, kind in enumerate(cfg.pattern[:rem])}
    return {"units": stacked, "rem": remc}


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    units, rem = cfg.units_and_rem
    k_embed, k_units, k_rem, k_final = jax.random.split(key, 4)
    params = {"embed": layers.init_embed(k_embed, cfg.vocab_size, cfg.d_model,
                                         dtype, cfg.tie_embeddings),
              "final_norm": layers.init_norm(cfg, dtype)}
    if units:
        unit_keys = jax.random.split(k_units, units)
        params["units"] = jax.vmap(
            lambda k: _init_unit(k, cfg, cfg.pattern, dtype))(unit_keys)
    if rem:
        params["rem"] = _init_unit(k_rem, cfg, cfg.pattern[:rem], dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _rope_for(cfg, positions):
    if not cfg.num_heads:
        return (None, None)
    return layers.rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _run_stack(params_stacked, x, cfg, pattern, rope, mode, caches, pos):
    """scan over stacked units; caches go in as xs and come out as ys.
    cfg.unroll replaces the scan with a Python loop (cost-probe mode)."""
    def unit_fn(carry, xs):
        xc, aux = carry
        up, uc = xs
        new_uc = {}
        for i, kind in enumerate(pattern):
            name = f"{i}_{kind}"
            c = uc[name] if uc else None
            xc, cnew, a = _apply_layer(up[name], xc, cfg, kind, rope, mode, c, pos)
            new_uc[name] = cnew if cnew is not None else jnp.zeros((), jnp.float32)
            aux = aux + a
        return (xc, aux), new_uc

    if cfg.unroll:
        n_units = jax.tree.leaves(params_stacked)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for u in range(n_units):
            up = jax.tree.map(lambda t: t[u], params_stacked)
            uc = (jax.tree.map(lambda t: t[u], caches)
                  if caches is not None else None)
            carry, yc = unit_fn(carry, (up, uc))
            outs.append(yc)
        x, aux = carry
        if caches is None:
            return x, aux, None
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, aux, new_caches
    if caches is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, up: unit_fn(c, (up, None)), (x, jnp.zeros((), jnp.float32)),
            params_stacked)
        return x, aux, None
    (x, aux), new_caches = jax.lax.scan(
        unit_fn, (x, jnp.zeros((), jnp.float32)), (params_stacked, caches))
    return x, aux, new_caches


def forward(params, cfg, tokens, *, prefix_embeds=None, mode="train",
            cache=None, pos=None, last_only=False):
    """tokens: (B, S) int32.  prefix_embeds: (B, P, D) early-fusion embeddings
    (VLM patches / audio frames) prepended to the token embeddings.

    Returns (logits (B, S_total, V), aux, new_cache).
    """
    x = layers.embed_tokens(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    if mode == "decode":
        positions = jnp.full((1,), pos)
    else:
        positions = jnp.arange(S)
    rope = _rope_for(cfg, positions)

    units, rem = cfg.units_and_rem
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"units": {}, "rem": {}}
    if units:
        ucache = cache["units"] if cache is not None else None
        x, aux, uc = _run_stack(params["units"], x, cfg, cfg.pattern, rope,
                                mode, ucache, pos)
        aux_total += aux
        if uc is not None:
            new_cache["units"] = uc
    if rem:
        rpattern = cfg.pattern[:rem]
        rcache = cache["rem"] if cache is not None else None
        for i, kind in enumerate(rpattern):
            name = f"{i}_{kind}"
            c = rcache[name] if rcache is not None else None
            x, cnew, a = _apply_layer(params["rem"][name], x, cfg, kind, rope,
                                      mode, c, pos)
            aux_total += a
            if cache is not None:
                new_cache["rem"][name] = cnew
    if last_only:  # prefill only needs the last position's logits
        x = x[:, -1:]
    x = layers.apply_norm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x)
    return logits, aux_total, (new_cache if cache is not None else None)


# ---------------------------------------------------------------------------
# Losses and serve steps
# ---------------------------------------------------------------------------

def train_loss(params, cfg, batch, aux_weight: float = 0.01):
    """batch: {'tokens': (B, S), optional 'prefix_embeds': (B, P, D)}.
    Next-token CE over token positions (prefix positions excluded)."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    logits, aux, _ = forward(params, cfg, tokens, prefix_embeds=prefix,
                             mode="train")
    P = 0 if prefix is None else prefix.shape[1]
    logits_t = logits[:, P:, :]               # text positions
    lp = jax.nn.log_softmax(logits_t[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = nll.mean() + aux_weight * aux
    return loss


def prefill(params, cfg, tokens, cache, *, prefix_embeds=None,
            last_only=False):
    logits, _, cache = forward(params, cfg, tokens, prefix_embeds=prefix_embeds,
                               mode="prefill", cache=cache, last_only=last_only)
    return logits[:, -1:], cache


def decode_step(params, cfg, token, cache, pos):
    """token: (B, 1) int32; pos: scalar int32 absolute position."""
    logits, _, cache = forward(params, cfg, token, mode="decode", cache=cache,
                               pos=pos)
    return logits, cache
