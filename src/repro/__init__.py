"""Reproduction of "Optimal Complexity in Non-Convex Decentralized Learning
over Time-Varying Networks" as a production-scale jax system.

Importing :mod:`repro` installs the jax compatibility shims in
:mod:`repro._compat` (newer mesh API emulated on jax 0.4.x) before any mesh
or sharding machinery is touched.
"""

from . import _compat  # noqa: F401  (must run before any mesh use)
