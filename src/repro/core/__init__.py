"""Core contribution of the paper: time-varying topologies, gossip weight
matrices, effective diameter, decentralized algorithms (DSGD/DSGT/MC-DSGT)
and the lower-bound hard instances."""

from . import algorithms, gossip, lower_bound, topology  # noqa: F401
from .algorithms import dsgd, dsgt, mc_dsgt, mix, multi_consensus, run, warm_start  # noqa: F401
from .gossip import (  # noqa: F401
    WeightSchedule,
    check_assumption3,
    consensus_contraction,
    laplacian_rule,
    metropolis_weights,
    mixing_beta,
    schedule_from_topology,
    theorem3_weight_schedule,
)
from .topology import (  # noqa: F401
    effective_diameter,
    effective_distance,
    federated_schedule,
    one_peer_exponential_schedule,
    sun_shaped_graph,
    sun_shaped_schedule,
    theorem3_distance_formula,
)
