"""Core contribution of the paper: time-varying topologies, gossip weight
matrices, effective diameter, the single-source update-rule engine behind
every decentralized algorithm (DSGD / DSGT / MC-DSGT / D² / local_sgd /
gt_local), the unified training driver, and the lower-bound hard instances
— plus the structure-aware gossip planning layer (GossipPlan) that lowers
every topology to its cheapest collective."""

from . import algorithms, driver, engine, gossip, lower_bound, topology  # noqa: F401
from .algorithms import (  # noqa: F401
    complete_mix,
    d2,
    dsgd,
    dsgt,
    from_rule,
    gt_local,
    local_sgd,
    make_plan_mixer,
    mc_dsgt,
    mix,
    multi_consensus,
    one_peer_mix,
    run,
    sun_mix,
    warm_start,
)
from .engine import ALGORITHMS, EngineOps, EngineState, UpdateRule, make_rule  # noqa: F401
from .gossip import (  # noqa: F401
    GossipPlan,
    GossipRound,
    WeightSchedule,
    check_assumption3,
    consensus_contraction,
    laplacian_rule,
    metropolis_weights,
    mixing_beta,
    plan_round,
    schedule_from_topology,
    theorem3_weight_schedule,
)
from .topology import (  # noqa: F401
    RoundStructure,
    classify_adjacency,
    effective_diameter,
    effective_distance,
    erdos_renyi_graph,
    erdos_renyi_schedule,
    federated_schedule,
    one_peer_exponential_schedule,
    random_matching_schedule,
    resampled_matching_schedule,
    star_graph,
    sun_shaped_graph,
    sun_shaped_schedule,
    theorem3_distance_formula,
)
