"""Unified training driver: the ONE place that stages gossip on device,
gathers per-step windows in-jit, warm-starts (or restores), records
eval/history, and runs the checkpoint cadence.

Consumed by :func:`repro.core.algorithms.run` (host reference),
:mod:`repro.launch.train` (distributed CLI), ``benchmarks/run.py`` and the
examples — none of them hand-roll a staging/driver loop anymore.

The staging contract (shared by every path): the whole schedule window —
one period of dense matrices, or the gossip plan's tensors — crosses the
host boundary ONCE, and the jitted step gathers its ``weights_per_step``
rounds by ``t % period`` index.  No per-step ``stacked()`` or host
transfer.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Gossip staging + in-jit window gather
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagedGossip:
    """Device-resident gossip for a whole run.

    ``impl='dense'``: ``arrays`` is the (period, n, n) stacked window;
    the bound step gathers ``wps`` rounds by index.  ``impl='auto'``:
    ``arrays`` is the staged :class:`repro.core.gossip.GossipPlan` tensors;
    the step receives them plus the start round ``t``.
    """

    impl: str
    arrays: Any
    period: int
    wps: int
    static_t: bool = False


def stage_plan(plan) -> dict:
    """Upload a :class:`repro.core.gossip.GossipPlan`'s tensors to device
    ONCE — the canonical staging entry (``dist.collectives.stage_plan``
    delegates here).  The returned dict is passed unchanged to every jitted
    step, which indexes it by ``t % period``."""
    return jax.tree.map(jnp.asarray, plan.tensors())


def stage(schedule, *, wps: int, impl: str = "dense", total: int | None = None,
          plan=None, static_t: bool = False) -> StagedGossip:
    """Stage ``schedule`` on device once.

    ``total`` caps the dense window (host runs stage ``min(period, total)``
    rounds; pass None to always stage one full period — the CLI does, so a
    ``--restore`` continuation lands on the right phase).  For ``auto``,
    ``plan`` is the GossipPlan (defaults to one planned period).
    """
    if impl == "auto":
        if plan is None:
            plan = schedule.plan(0, schedule.period)
        return StagedGossip("auto", stage_plan(plan), plan.period, wps,
                            static_t=static_t)
    period = getattr(schedule, "period", None) or (total or 1)
    if total is not None:
        period = min(period, total)
    arrays = jnp.asarray(schedule.stacked(0, period))
    return StagedGossip("dense", arrays, period, wps)


def bind_step(staged: StagedGossip, core_step):
    """Jit ``core_step`` against the staged gossip.

    ``core_step(state, extra, gossip, t)`` — ``extra`` is the per-step
    input (a batch, a PRNG key, ...).  Dense: ``gossip`` arrives as the
    step's gathered ``(wps, n, n)`` window.  Auto: ``gossip`` is the plan
    tensors and ``t`` the start round (static when the plan dispatch is).

    Returns ``step(state, extra, t) -> (state, out)`` with the staged
    arrays closed over.
    """
    if staged.impl == "auto":
        fn = (jax.jit(core_step, static_argnums=3) if staged.static_t
              else jax.jit(core_step))
        return lambda state, extra, t: fn(state, extra, staged.arrays, t)

    wps, period = staged.wps, staged.period

    def gathered(state, extra, Ws_all, t):
        idx = (t + jnp.arange(wps)) % period
        return core_step(state, extra, jnp.take(Ws_all, idx, axis=0), t)

    fn = jax.jit(gathered)
    return lambda state, extra, t: fn(state, extra, staged.arrays, t)


# ---------------------------------------------------------------------------
# Restore-or-warm + the loop
# ---------------------------------------------------------------------------

def restore_or_warm(state, *, restore: Optional[str] = None, load_fn=None,
                    warm: Optional[Callable] = None, spec=None):
    """Either restore ``(state, start_step)`` from a checkpoint or apply the
    rule's warm start — never both (a checkpoint already holds warm state).

    ``spec`` is the current run's :class:`repro.exp.ExperimentSpec` (when
    the caller has one): if the checkpoint was written with a
    reproducibility manifest (``<restore>.spec.json``), any mismatch on a
    scenario-defining field raises a warning before the restore proceeds.
    """
    if restore:
        if spec is not None:
            from ..exp import manifest as _mf  # deferred: exp imports core
            _mf.check_restore_spec(restore, spec)
        state, start_step = load_fn(restore, state)
        return state, int(start_step)
    return (warm(state) if warm is not None else state), 0


def run_loop(step, state, *, steps: int, wps: int, period: int,
             start_step: int = 0, extra_fn: Optional[Callable] = None,
             record: Optional[Callable] = None,
             checkpoint: Optional[str] = None, checkpoint_every: int = 50,
             save_fn=None, tracer=None):
    """The training loop every runtime shares.

    ``step(state, extra, t)`` — a :func:`bind_step` result; ``t`` advances
    by ``wps`` per step, taken modulo ``period``, and continues from
    ``start_step * wps`` so restored runs resume the schedule at the right
    phase.  ``extra_fn(k)`` supplies the per-step input.  ``record(k, t,
    state, out, dt)`` is called after every step; non-None returns are
    appended to the history.  ``save_fn(path, state, step)`` runs every
    ``checkpoint_every`` steps and once at the end.

    ``tracer`` is an optional :class:`repro.obs.trace.Tracer`: each loop
    phase (``data`` = extra_fn, ``step`` = the jitted step dispatch,
    ``telemetry`` = the record hook, ``checkpoint`` = save_fn) runs inside
    a wall-clock span + ``jax.profiler.TraceAnnotation``.
    """
    span = (tracer.span if tracer is not None
            else (lambda phase: contextlib.nullcontext()))
    history = []
    t = start_step * wps
    last = start_step + steps - 1
    for k in range(start_step, start_step + steps):
        with span("data"):
            extra = extra_fn(k) if extra_fn is not None else None
        t0 = time.time()
        with span("step"):
            state, out = step(state, extra, t % period)
        dt = time.time() - t0
        t += wps
        if record is not None:
            with span("telemetry"):
                rec = record(k, t, state, out, dt)
            if rec is not None:
                history.append(rec)
        if checkpoint and save_fn is not None and \
                (k + 1) % checkpoint_every == 0 and k != last:
            with span("checkpoint"):
                save_fn(checkpoint, state, k + 1)
    if checkpoint and save_fn is not None:
        with span("checkpoint"):
            save_fn(checkpoint, state, start_step + steps)
    return state, history


# ---------------------------------------------------------------------------
# Host-reference convenience (algorithms.run and the examples)
# ---------------------------------------------------------------------------

def run_algorithm(algo, x0: PyTree, grad_fn, weight_schedule, num_steps: int,
                  key: jax.Array, eval_fn=None, eval_every: int = 1,
                  gossip_impl: str = "dense", plan=None, telemetry=None,
                  obs: tuple = (), tracer=None):
    """Drive a host :class:`repro.core.algorithms.DecentralizedAlgorithm`
    over a :class:`repro.core.gossip.WeightSchedule`.

    ``gossip_impl='dense'`` stages one window of dense matrices;
    ``'auto'`` lowers the schedule through ``weight_schedule.plan`` and
    mixes via :func:`repro.core.algorithms.plan_step` — the same per-round
    structured dispatch the distributed runtime uses (``plan`` overrides
    the default one-period plan).  ``telemetry`` is an optional
    :class:`repro.sim.telemetry.TelemetryRecorder` or
    :class:`repro.obs.metrics.ObsRecorder` (anything with the
    ``record(k, t, state, out, dt)`` hook signature) invoked every step;
    when it also exposes ``eval_event(k, t, value)``, every recorded
    ``eval_fn`` point is forwarded to it (the optimality-gap feed).

    ``obs`` names in-jit metric scalars (:data:`repro.core.engine.
    OBS_METRICS`) to compute inside the step; they arrive at the record
    hook as ``out["obs"]`` device scalars.  ``tracer`` adds per-phase
    wall-clock spans to the loop (see :func:`run_loop`).

    Returns (final_state, history) where history records ``eval_fn`` of the
    node-mean model x̄ every ``eval_every`` steps (plus the final step),
    keyed by the total gossip/oracle budget T consumed so far (the paper's
    Figure 2 x-axis).
    """
    state = algo.init(x0)
    key, k0 = jax.random.split(key)
    state = algo.warm(state, grad_fn, k0)
    wps = algo.weights_per_step
    total = max(1, num_steps * wps)
    obs = tuple(obs)
    if gossip_impl == "auto":
        from . import algorithms as alg  # deferred: algorithms imports driver
        if plan is None:
            plan = weight_schedule.plan(0, weight_schedule.period)
        pstep = alg.plan_step(algo, plan)
        staged = stage(weight_schedule, wps=wps, impl="auto", plan=plan,
                       static_t=(pstep.dispatch == "static"))

        def core(state, sub, tensors, t):
            out = pstep(state, grad_fn, tensors, t, sub, obs=obs)
            return (out[0], {"obs": out[1]}) if obs else (out, None)
    else:
        staged = stage(weight_schedule, wps=wps, total=total)

        def core(state, sub, weights, t):
            out = algo.step(state, grad_fn, weights, sub, obs=obs)
            return (out[0], {"obs": out[1]}) if obs else (out, None)

    step = bind_step(staged, core)

    def extra_fn(k):
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def record(k, t, state, out, dt):
        if telemetry is not None:
            telemetry.record(k, t, state, out, dt)
        if eval_fn is None:
            return None
        if k % eval_every == 0 or k == num_steps - 1:
            xbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)
            val = jax.device_get(eval_fn(xbar))
            if telemetry is not None and hasattr(telemetry, "eval_event"):
                telemetry.eval_event(k, t, val)
            return (t, val)
        return None

    return run_loop(step, state, steps=num_steps, wps=wps,
                    period=staged.period, extra_fn=extra_fn, record=record,
                    tracer=tracer)
