"""Time-varying communication topologies (paper §2-3).

A topology schedule is a callable ``t -> adjacency`` where ``adjacency`` is a
boolean (n, n) numpy array with ``adj[i, j] == True`` iff the directed link
(j, i) is active at round t (node j can send to node i).  Self-loops are
implied everywhere (``N_G(i)`` always contains i, paper Notations) and are
stored explicitly on the diagonal for convenience.

Everything here is host-side scheduling logic over tiny (n <= 64) graphs, so
plain numpy is used; the distributed runtime consumes the *weight matrices*
built from these graphs (see :mod:`repro.core.gossip`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

Adjacency = np.ndarray  # (n, n) bool, adj[i, j]: j -> i active
Schedule = Callable[[int], Adjacency]


# ---------------------------------------------------------------------------
# Static graph constructors
# ---------------------------------------------------------------------------

def _empty(n: int) -> Adjacency:
    adj = np.zeros((n, n), dtype=bool)
    np.fill_diagonal(adj, True)
    return adj


def complete_graph(n: int) -> Adjacency:
    return np.ones((n, n), dtype=bool)


def star_graph(n: int, center: int = 0) -> Adjacency:
    adj = _empty(n)
    adj[center, :] = True
    adj[:, center] = True
    return adj


def ring_graph(n: int) -> Adjacency:
    adj = _empty(n)
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = True
    adj[idx, (idx - 1) % n] = True
    return adj


def static_exponential_graph(n: int) -> Adjacency:
    """Each node links to peers at hop distance 2^k (Assran et al. [4])."""
    adj = _empty(n)
    hops = [2 ** k for k in range(max(1, int(math.ceil(math.log2(n)))))] if n > 1 else []
    for i in range(n):
        for h in hops:
            adj[i, (i + h) % n] = True
            adj[(i + h) % n, i] = True
    return adj


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> Adjacency:
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, 1)
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    return adj


def sun_shaped_graph(n: int, center_set: Sequence[int]) -> Adjacency:
    """Sun-shaped graph S_{n,C} (Definition 1).

    Nodes in C are connected to everyone (C itself forms a complete
    subgraph); rim nodes connect only to C (plus the implicit self-loop).
    """
    center = np.asarray(sorted(set(center_set)), dtype=int)
    if center.size == 0:
        raise ValueError("center set must be non-empty")
    if center.min() < 0 or center.max() >= n:
        raise ValueError(f"center set {center} out of range for n={n}")
    adj = _empty(n)
    adj[center, :] = True
    adj[:, center] = True
    return adj


# ---------------------------------------------------------------------------
# Per-round structure descriptors (gossip-planning layer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundStructure:
    """What a single round's graph *is*, beyond its adjacency matrix.

    The gossip planner (:meth:`repro.core.gossip.WeightSchedule.plan`) uses
    these tags to lower each round to its cheapest collective:

    * ``empty``     — self-loops only: no communication at all;
    * ``complete``  — K_n: one all-reduce of the node mean;
    * ``matching``  — (possibly partial) matching (``perm`` is the peer
                      involution, fixing unmatched nodes): one point-to-point
                      exchange, O(V) on the wire.  Partial matchings arise
                      when a channel fault drops pairs out of a perfect
                      matching (:mod:`repro.sim.channel`);
    * ``sun``       — S_{n,C} (``center`` is C): two node-axis all-reduces,
                      O(2V) on the wire;
    * ``dense``     — anything else: the generic einsum / all-gather path.
    """

    kind: str                                  # dense|sun|matching|complete|empty
    center: tuple | None = None                # sun: sorted center set C
    perm: tuple | None = None                  # matching: peer involution


def classify_adjacency(adj: Adjacency) -> RoundStructure:
    """Classify one adjacency matrix into a :class:`RoundStructure`.

    Recognition is exact (no tolerance): directed or otherwise unstructured
    graphs fall through to ``dense``, which is always a valid lowering.
    """
    n = adj.shape[0]
    if not np.array_equal(adj, adj.T):
        return RoundStructure("dense")
    off = adj & ~np.eye(n, dtype=bool)
    deg = off.sum(axis=1)
    if not deg.any():
        return RoundStructure("empty")
    if (deg == n - 1).all():
        return RoundStructure("complete")
    if (deg <= 1).all():
        # perfect OR partial matching: unmatched (degree-0) nodes are fixed
        # points of the involution, so a fault-degraded matching still
        # lowers to the one-peer exchange
        perm = np.where(deg == 1, off.argmax(axis=1), np.arange(n))
        if np.array_equal(perm[perm], np.arange(n)):
            return RoundStructure("matching", perm=tuple(int(p) for p in perm))
    center = np.flatnonzero(deg == n - 1)
    if center.size:
        want = np.zeros(n, dtype=bool)
        want[center] = True
        rim = np.setdiff1d(np.arange(n), center)
        if all(np.array_equal(off[i], want & (np.arange(n) != i)) for i in rim):
            return RoundStructure("sun", center=tuple(int(c) for c in center))
    return RoundStructure("dense")


# ---------------------------------------------------------------------------
# Time-varying schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StaticSchedule:
    """Constant graph: G^t = G for all t."""

    adjacency: Adjacency

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    @property
    def period(self) -> int:
        return 1

    def __call__(self, t: int) -> Adjacency:
        return self.adjacency

    def structure(self, t: int) -> RoundStructure:
        return classify_adjacency(self.adjacency)


@dataclasses.dataclass(frozen=True)
class PeriodicSchedule:
    """G^t cycles through a finite list of graphs."""

    graphs: tuple

    @property
    def n(self) -> int:
        return self.graphs[0].shape[0]

    @property
    def period(self) -> int:
        return len(self.graphs)

    def __call__(self, t: int) -> Adjacency:
        return self.graphs[t % len(self.graphs)]

    def structure(self, t: int) -> RoundStructure:
        return classify_adjacency(self(t))


def one_peer_exponential_schedule(n: int) -> PeriodicSchedule:
    """One-peer exponential graph (Ying et al. [42]): at round t every node i
    talks to exactly one peer at hop 2^(t mod log2 n).  Requires n a power
    of two."""
    if n & (n - 1):
        raise ValueError(f"one-peer exponential requires power-of-two n, got {n}")
    tau = max(1, int(math.log2(n)))
    graphs = []
    for k in range(tau):
        adj = _empty(n)
        idx = np.arange(n)
        peer = idx ^ (2 ** k)  # hypercube matching: involution, one peer each
        adj[idx, peer] = True
        adj[peer, idx] = True
        graphs.append(adj)
    return PeriodicSchedule(tuple(graphs))


def random_matching_schedule(n: int, period: int = 16, seed: int = 0) -> PeriodicSchedule:
    """EquiRand/MATCHA-flavoured schedule: each round activates a uniformly
    random perfect matching (n even), so every node talks to exactly one
    peer per round [32, 39]."""
    if n % 2:
        raise ValueError("random matching requires even n")
    rng = np.random.default_rng(seed)
    return PeriodicSchedule(tuple(_random_matching(n, rng)
                                  for _ in range(period)))


def _random_matching(n: int, rng: np.random.Generator) -> Adjacency:
    perm = rng.permutation(n)
    adj = _empty(n)
    for a, b in zip(perm[0::2], perm[1::2]):
        adj[a, b] = adj[b, a] = True
    return adj


def erdos_renyi_schedule(n: int, p: float = 0.5, period: int = 8,
                         seed: int = 0) -> PeriodicSchedule:
    """Time-varying Erdős–Rényi graphs: each of the ``period`` rounds is an
    independent G(n, p) draw (plus self-loops).  Unstructured by design —
    the gossip planner lowers every round to the dense path — so it serves
    as the generic-topology scenario surface and the planner's control
    case."""
    rng = np.random.default_rng(seed)
    graphs = tuple(
        erdos_renyi_graph(n, p, seed=int(rng.integers(2 ** 31)))
        for _ in range(period))
    return PeriodicSchedule(graphs)


@dataclasses.dataclass(frozen=True)
class ResampledMatchingSchedule:
    """Non-periodic random-matching schedule: round t activates a fresh
    uniformly random perfect matching drawn from a seed stream keyed by
    ``(seed, t)`` — no round is ever reused, unlike the periodic
    :func:`random_matching_schedule`.

    ``period`` is ``None``: consumers that need a finite window (the gossip
    planner, :func:`repro.core.gossip.schedule_from_topology`) materialize a
    ``horizon`` of rounds instead."""

    n: int
    seed: int = 0

    period = None  # non-periodic: every round is a fresh draw

    def __call__(self, t: int) -> Adjacency:
        rng = np.random.default_rng(np.random.SeedSequence((self.seed, t)))
        return _random_matching(self.n, rng)

    def structure(self, t: int) -> RoundStructure:
        return classify_adjacency(self(t))


def resampled_matching_schedule(n: int, seed: int = 0) -> ResampledMatchingSchedule:
    if n % 2:
        raise ValueError("random matching requires even n")
    return ResampledMatchingSchedule(n, seed)


def federated_schedule(n: int, local_steps: int) -> PeriodicSchedule:
    """Federated averaging as a time-varying network: ``local_steps`` rounds
    of the empty (self-loop only) graph followed by one complete-graph round
    (paper §1: "alternating between global averaging and local updates")."""
    graphs = [_empty(n)] * local_steps + [complete_graph(n)]
    return PeriodicSchedule(tuple(graphs))


def sun_shaped_schedule(
    n: int,
    beta: float,
    avoid: Sequence[int] = (),
) -> PeriodicSchedule:
    """Theorem 3 construction: rotating sun-shaped graphs.

    Picks ``k = ceil(n * (1 - beta))`` center nodes per round, rotating the
    center set through ``p = floor((n - |avoid|) / k)`` disjoint subsets of
    ``[n] \\ avoid``.  ``avoid`` is the union of the two "far" sets I1, I2
    from the lower-bound construction (their nodes never serve as centers);
    pass ``avoid=()`` for the generic training schedule.
    """
    if not 0.0 <= beta <= 1.0 - 1.0 / n + 1e-12:
        raise ValueError(f"Theorem 3 requires beta in [0, 1-1/n]; got {beta} (n={n})")
    k = int(math.ceil(n * (1.0 - beta)))
    k = min(max(k, 1), n)
    avoid_set = sorted(set(avoid))
    pool = [i for i in range(n) if i not in avoid_set]
    if k >= n:
        return PeriodicSchedule((complete_graph(n),))
    p = len(pool) // k
    if p == 0:
        # Fewer than k nodes outside `avoid`: no avoid-respecting chunking
        # exists (paper: p = 0), so the center must dip into `avoid`; any two
        # sets are then at effective distance 1, matching eq. (5).
        center = (pool + avoid_set)[:k]
        return PeriodicSchedule((sun_shaped_graph(n, center),))
    graphs = [sun_shaped_graph(n, pool[q * k:(q + 1) * k]) for q in range(p)]
    return PeriodicSchedule(tuple(graphs))


# ---------------------------------------------------------------------------
# Effective distance / diameter (Definition 2)
# ---------------------------------------------------------------------------

def _frontier_rounds(schedule: Schedule, start: frozenset, targets: frozenset,
                     t0: int, max_rounds: int) -> int:
    """Rounds until any node of ``targets`` enters the neighborhood closure of
    ``start``, communicating over G^{t0}, G^{t0+1}, ... (inf if > max_rounds).

    NOTE on orientation: Definition 2 composes neighborhoods as
    N_{G^t}(N_{G^{t+1}}(... N_{G^{t+R-1}}(i)...)) — the innermost (first
    expansion) uses the *latest* graph.  For undirected graphs — all the
    paper's constructions — composition order does not change the reach-time
    set sizes, and we expand forward in time which matches how messages
    physically propagate; tests pin this equivalence on the Theorem 3
    schedules.
    """
    n = schedule(0).shape[0]
    reached = np.zeros(n, dtype=bool)
    reached[list(start)] = True
    tgt = np.zeros(n, dtype=bool)
    tgt[list(targets)] = True
    if (reached & tgt).any():
        return 0
    for r in range(1, max_rounds + 1):
        adj = schedule(t0 + r - 1)
        reached = reached | (adj[:, reached].any(axis=1))
        if (reached & tgt).any():
            return r
    return max_rounds + 1


def effective_distance(schedule, set_a: Sequence[int], set_b: Sequence[int],
                       period: int | None = None, max_rounds: int | None = None) -> int:
    """dist_{{G^t}}(I1, I2) per Definition 2, for periodic schedules.

    The minimum over start rounds t of the max over both directions of the
    frontier reach time.  For periodic schedules only the start round
    ``t mod period`` matters.
    """
    n = schedule(0).shape[0]
    p = period if period is not None else getattr(schedule, "period", 1)
    cap = max_rounds if max_rounds is not None else n * p + n + 1
    a, b = frozenset(set_a), frozenset(set_b)
    best = cap + 1
    for t0 in range(p):
        fwd = _frontier_rounds(schedule, a, b, t0, cap)
        bwd = _frontier_rounds(schedule, b, a, t0, cap)
        best = min(best, max(fwd, bwd))
    return best


def _all_pairs_first_reach(schedule: Schedule, t0: int,
                           max_rounds: int) -> np.ndarray:
    """``first[i, j]`` = rounds until j enters the neighborhood closure of
    {i}, communicating over G^{t0}, G^{t0+1}, ... (``max_rounds + 1`` when it
    never does) — every source propagated at once as one boolean frontier
    matrix per round, instead of n independent single-source scans."""
    n = schedule(t0).shape[0]
    reach = np.eye(n, dtype=bool)
    first = np.where(reach, 0, max_rounds + 1)
    for r in range(1, max_rounds + 1):
        if reach.all():
            break
        adj = schedule(t0 + r - 1)
        # closure step for every source s at once:
        # reach'[s, i] = reach[s, i] OR any_j (adj[i, j] AND reach[s, j])
        new = reach | ((reach.astype(np.int32) @ adj.T.astype(np.int32)) > 0)
        first[new & ~reach] = r
        reach = new
    return first


def effective_diameter(schedule, period: int | None = None) -> int:
    """max over node pairs of the Definition 2 effective distance — one
    all-pairs frontier propagation per start round (exactly equal to the
    pairwise :func:`effective_distance` scan it replaces; pinned by tests
    on the Theorem 3 schedules)."""
    n = schedule(0).shape[0]
    if n <= 1:
        return 0
    p = period if period is not None else getattr(schedule, "period", 1)
    if p is None:
        raise ValueError("non-periodic schedule requires period=<rounds>")
    cap = n * p + n + 1
    best = np.full((n, n), cap + 1, dtype=np.int64)
    for t0 in range(p):
        first = _all_pairs_first_reach(schedule, t0, cap)
        np.minimum(best, np.maximum(first, first.T), out=best)
    return int(best[~np.eye(n, dtype=bool)].max())


def _effective_diameter_pairwise(schedule, period: int | None = None) -> int:
    """Reference implementation (O(n^2) single-source scans) kept for the
    equality pin in tests."""
    n = schedule(0).shape[0]
    diam = 0
    for i in range(n):
        for j in range(i + 1, n):
            diam = max(diam, effective_distance(schedule, (i,), (j,), period))
    return diam


def theorem3_distance_formula(n: int, beta: float, size_a: int, size_b: int) -> int:
    """The exact effective distance of the Theorem 3 construction, eq. (5):
    floor((n - |I1| - |I2|) / ceil(n(1-beta))) + 1."""
    if size_a + size_b >= n:
        return 1
    k = int(math.ceil(n * (1.0 - beta)))
    return (n - size_a - size_b) // k + 1
