"""Decentralized stochastic algorithms (paper §5, Table 1).

All three algorithms operate on *stacked* pytrees: every leaf carries a
leading node dimension ``n`` and node i's model copy lives at index i.  The
same functions drive

* the host/single-process reference used by the paper-claims benchmarks
  (leaves are small dense arrays), and
* the distributed runtime (leaves are sharded over the mesh node axis and
  the einsum gossip lowers to cross-node collectives; see
  :mod:`repro.dist.steps`).

``grad_fn(x_stacked, key) -> g_stacked`` must return one stochastic-oracle
sample per node (Assumption 2); MC-DSGT performs its R-sample gradient
accumulation internally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
GradFn = Callable[[PyTree, jax.Array], PyTree]


# ---------------------------------------------------------------------------
# Gossip primitives on stacked pytrees
# ---------------------------------------------------------------------------

def mix(W: jax.Array, tree: PyTree) -> PyTree:
    """z_i = sum_j W[i, j] y_j on every leaf (partial-averaging protocol)."""
    def _m(x):
        return jnp.einsum("ij,j...->i...", W.astype(x.dtype), x)
    return jax.tree.map(_m, tree)


def multi_consensus(Ws: jax.Array, tree: PyTree, *, unroll: bool = False) -> PyTree:
    """Algorithm 2: apply W^{t1}, ..., W^{t2-1} in sequence.  ``Ws`` is the
    (R, n, n) stack for the window [t1, t2).  ``unroll`` replaces the scan
    with a Python loop (cost-probe lowering)."""
    if unroll:
        out = tree
        for r in range(Ws.shape[0]):
            out = mix(Ws[r], out)
        return out
    def body(z, W):
        return mix(W, z), None
    out, _ = jax.lax.scan(body, tree, Ws)
    return out


def sun_mix(center_mask: jax.Array, delta: float, tree: PyTree) -> PyTree:
    """Structured gossip for sun-shaped graphs (beyond-paper optimization).

    For W = I - (delta/n) L(S_{n,C}) the mixing decomposes into elementwise
    ops plus two node-axis sums:

        rim i:    z_i = y_i - (d/n)(k y_i)     + (d/n) * sum_{c in C} y_c
        center c: z_c = y_c - (d/n)(n y_c)     + (d/n) * sum_{all j} y_j

    Under GSPMD the two sums lower to all-reduces of ONE parameter volume
    each — O(2 V) on the wire instead of the O(n V) all-gather the dense
    einsum needs.  Exactly equal to mix(W, tree) for sun-shaped W.

    center_mask: (n,) float 0/1; delta = n(1-beta)/ceil(n(1-beta)).
    """
    n = center_mask.shape[0]
    k = jnp.sum(center_mask)

    def _m(x):
        m = center_mask.astype(x.dtype).reshape((n,) + (1,) * (x.ndim - 1))
        kx = k.astype(x.dtype)
        St = jnp.sum(x, axis=0, keepdims=True)
        Sc = jnp.sum(x * m, axis=0, keepdims=True)
        degp = kx + (n - kx) * m
        return x - (delta / n) * (degp * x) + (delta / n) * (Sc + m * (St - Sc))

    return jax.tree.map(_m, tree)


def sun_multi_consensus(center_masks: jax.Array, delta: float, tree: PyTree,
                        *, unroll: bool = True) -> PyTree:
    """Algorithm 2 specialised to a sun-shaped schedule: apply R structured
    mixings.  center_masks: (R, n)."""
    if unroll:
        out = tree
        for r in range(center_masks.shape[0]):
            out = sun_mix(center_masks[r], delta, out)
        return out

    def body(z, mask):
        return sun_mix(mask, delta, z), None

    out, _ = jax.lax.scan(body, tree, center_masks)
    return out


def one_peer_mix(peer: jax.Array, w_peer: float, tree: PyTree) -> PyTree:
    """Gossip for one-peer (perfect-matching) graphs — one-peer exponential
    [42], EquiRand/random matching [32, 39]: z_i = (1-w) y_i + w y_{peer(i)}.

    ``peer`` is the (n,) matching permutation (an involution).  Under GSPMD
    the node-axis take lowers to a collective-permute — O(V) point-to-point
    instead of the dense einsum's O(nV) gather (beyond-paper).
    """
    def _m(x):
        return (1.0 - w_peer) * x + w_peer * jnp.take(x, peer, axis=0)
    return jax.tree.map(_m, tree)


def one_peer_mix_ppermute(perm: list, w_peer: float, tree: PyTree,
                          mesh, axis: str = "data") -> PyTree:
    """shard_map + lax.ppermute form of :func:`one_peer_mix` — the explicit
    point-to-point schedule (GSPMD lowers the take-based form to a full
    all-gather; this one provably emits collective-permute).

    perm: static list of (src, dst) node pairs (the matching, both
    directions).  Node axis must be fully sharded over ``axis``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _mix_shard(x):
        y = jax.lax.ppermute(x, axis, perm)
        return (1.0 - w_peer) * x + w_peer * y

    def _m(x):
        spec = P(axis, *([None] * (x.ndim - 1)))
        return shard_map(_mix_shard, mesh=mesh, in_specs=spec,
                         out_specs=spec)(x)

    return jax.tree.map(_m, tree)


def node_mean(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=True), tree)


def broadcast_nodes(tree: PyTree, n: int) -> PyTree:
    """Stack n identical copies of an (unstacked) pytree."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


def _axpy(a: float | jax.Array, x: PyTree, y: PyTree) -> PyTree:
    return jax.tree.map(lambda u, v: v + a * u.astype(v.dtype), x, y)


def _accumulate(grad_fn: GradFn, x: PyTree, key: jax.Array, R: int) -> PyTree:
    """Gradient accumulation: (1/R) sum_r O(x; zeta_r)."""
    if R == 1:
        return grad_fn(x, key)
    keys = jax.random.split(key, R)
    shapes = jax.eval_shape(grad_fn, x, keys[0])
    zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def body(acc, k):
        return jax.tree.map(jnp.add, acc, grad_fn(x, k)), None

    acc, _ = jax.lax.scan(body, zero, keys)
    return jax.tree.map(lambda a: a / R, acc)


# ---------------------------------------------------------------------------
# Algorithm interfaces
# ---------------------------------------------------------------------------

class AlgoState(NamedTuple):
    x: PyTree            # stacked model copies
    h: Optional[PyTree]  # gradient tracker (None for DSGD)
    g_prev: Optional[PyTree]
    opt_state: Any
    k: jax.Array         # round counter


@dataclasses.dataclass(frozen=True)
class DecentralizedAlgorithm:
    """A decentralized optimizer: ``weights`` passed to ``step`` is the
    (rounds, n, n) stack of gossip matrices this round consumes (rounds =
    ``weights_per_step``)."""

    name: str
    weights_per_step: int
    init: Callable[[PyTree], AlgoState]
    step: Callable[[AlgoState, GradFn, jax.Array, jax.Array], AlgoState]


# -- DSGD [12] ---------------------------------------------------------------

def dsgd(gamma: float, local_opt=None) -> DecentralizedAlgorithm:
    """x^{k+1} = W^k (x^k - gamma * g^k)."""

    def init(x0: PyTree) -> AlgoState:
        opt_state = local_opt.init(x0) if local_opt else None
        return AlgoState(x=x0, h=None, g_prev=None, opt_state=opt_state,
                         k=jnp.zeros((), jnp.int32))

    def step(state: AlgoState, grad_fn: GradFn, weights: jax.Array,
             key: jax.Array) -> AlgoState:
        g = grad_fn(state.x, key)
        if local_opt:
            upd, opt_state = local_opt.update(g, state.opt_state)
        else:
            upd, opt_state = g, None
        x = _axpy(-gamma, upd, state.x)
        x = multi_consensus(weights, x)
        return AlgoState(x=x, h=None, g_prev=None, opt_state=opt_state,
                         k=state.k + 1)

    return DecentralizedAlgorithm("dsgd", 1, init, step)


# -- DSGT [40] ---------------------------------------------------------------

def dsgt(gamma: float) -> DecentralizedAlgorithm:
    """x^{k+1} = W^k (x^k - gamma h^k);  h^{k+1} = W^k (h^k + g^{k+1} - g^k).

    Consumes two gossip rounds per step (one for x, one for h), matching the
    accounting of Algorithm 1 with R = 1.
    """

    def init(x0: PyTree) -> AlgoState:
        return AlgoState(x=x0, h=None, g_prev=None, opt_state=None,
                         k=jnp.zeros((), jnp.int32))

    def step(state: AlgoState, grad_fn: GradFn, weights: jax.Array,
             key: jax.Array) -> AlgoState:
        if state.h is None:
            raise ValueError("call warm_start first (h requires g at x0)")
        Wx, Wh = weights[0], weights[1]
        _, k_g = jax.random.split(key)
        x = mix(Wx, _axpy(-gamma, state.h, state.x))
        g = grad_fn(x, k_g)
        h = mix(Wh, _axpy(1.0, g, _axpy(-1.0, state.g_prev, state.h)))
        return AlgoState(x=x, h=h, g_prev=g, opt_state=None, k=state.k + 1)

    return DecentralizedAlgorithm("dsgt", 2, init, step)


# -- MC-DSGT (Algorithm 1) ----------------------------------------------------

def mc_dsgt(gamma: float, R: int) -> DecentralizedAlgorithm:
    """Multi-Consensus DSGT: gradient accumulation over R oracle queries and
    R gossip rounds per consensus step.  ``weights`` is the (2R, n, n) stack
    [W^{2kR}, ..., W^{(2k+2)R - 1}]; the first R mix x, the last R mix h.
    """

    def init(x0: PyTree) -> AlgoState:
        return AlgoState(x=x0, h=None, g_prev=None, opt_state=None,
                         k=jnp.zeros((), jnp.int32))

    def step(state: AlgoState, grad_fn: GradFn, weights: jax.Array,
             key: jax.Array) -> AlgoState:
        if state.h is None:
            raise ValueError("call warm_start first (h^0 = averaged g at x0)")
        Wx, Wh = weights[:R], weights[R:]
        x = multi_consensus(Wx, _axpy(-gamma, state.h, state.x))
        g = _accumulate(grad_fn, x, key, R)
        h = multi_consensus(
            Wh, _axpy(1.0, g, _axpy(-1.0, state.g_prev, state.h)))
        return AlgoState(x=x, h=h, g_prev=g, opt_state=None, k=state.k + 1)

    return DecentralizedAlgorithm("mc_dsgt", 2 * R, init, step)


# -- D^2 [35] ------------------------------------------------------------------

def d2(gamma: float) -> DecentralizedAlgorithm:
    """D^2 (Tang et al. [35]): removes data-heterogeneity influence via the
    difference update x^{k+1} = W(2 x^k - x^{k-1} - gamma (g^k - g^{k-1})).
    Requires symmetric PSD W (the Theorem 3 matrices qualify).  Included as
    an extra Table-1-family baseline beyond the paper's DSGD/DSGT."""

    def init(x0: PyTree) -> AlgoState:
        return AlgoState(x=x0, h=None, g_prev=None, opt_state=None,
                         k=jnp.zeros((), jnp.int32))

    def step(state: AlgoState, grad_fn: GradFn, weights: jax.Array,
             key: jax.Array) -> AlgoState:
        if state.g_prev is None:
            raise ValueError("call warm_start first")
        x_prev = state.opt_state  # reuse the slot for x^{k-1}
        g = grad_fn(state.x, key)
        z = jax.tree.map(lambda xk, xm, gk, gm: 2 * xk - xm - gamma * (gk - gm),
                         state.x, x_prev, g, state.g_prev)
        x = mix(weights[0], z)
        return AlgoState(x=x, h=None, g_prev=g, opt_state=state.x,
                         k=state.k + 1)

    return DecentralizedAlgorithm("d2", 1, init, step)


def warm_start(algo: DecentralizedAlgorithm, state: AlgoState,
               grad_fn: GradFn, key: jax.Array) -> AlgoState:
    """Initialize the gradient tracker: g~^0 = accumulated grads at x^0 and
    h^0 = (1/n) sum_i g~_i^0 replicated (Algorithm 1's initialization)."""
    if algo.name == "dsgd":
        return state
    if algo.name == "d2":
        # first step reduces to DSGD: x^0_prev = x^0, g^{-1} = g^0... use
        # x_prev = x0 and g_prev = oracle at x0 so the first update is
        # x^1 = W(x^0 - gamma * 0) shifted; standard D^2 warm start uses one
        # DSGD step, which we emulate by setting g_prev = 0.
        g0 = jax.tree.map(jnp.zeros_like, state.x)
        return state._replace(g_prev=g0, opt_state=state.x)
    R = algo.weights_per_step // 2
    g0 = _accumulate(grad_fn, state.x, key, R)
    n = jax.tree.leaves(state.x)[0].shape[0]
    h0 = jax.tree.map(
        lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape), g0)
    return state._replace(h=h0, g_prev=g0)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run(algo: DecentralizedAlgorithm, x0: PyTree, grad_fn: GradFn,
        weight_schedule, num_steps: int, key: jax.Array,
        eval_fn: Optional[Callable[[PyTree], Any]] = None,
        eval_every: int = 1):
    """Host-side training loop over a :class:`repro.core.gossip.WeightSchedule`.

    Returns (final_state, history) where history records ``eval_fn`` of the
    node-mean model x-bar every ``eval_every`` rounds, keyed by the total
    gossip/oracle budget T = k * weights_per_step consumed so far (the
    paper's x-axis in Figure 2).
    """
    state = algo.init(x0)
    key, k0 = jax.random.split(key)
    state = warm_start(algo, state, grad_fn, k0)
    step = jax.jit(algo.step, static_argnums=1)
    history = []
    t = 0
    for k in range(num_steps):
        Ws = jnp.asarray(weight_schedule.stacked(t, algo.weights_per_step))
        key, sub = jax.random.split(key)
        state = step(state, grad_fn, Ws, sub)
        t += algo.weights_per_step
        if eval_fn is not None and (k % eval_every == 0 or k == num_steps - 1):
            xbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)
            history.append((t, jax.device_get(eval_fn(xbar))))
    return state, history
