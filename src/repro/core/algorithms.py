"""Decentralized stochastic algorithms (paper §5, Table 1).

All three algorithms operate on *stacked* pytrees: every leaf carries a
leading node dimension ``n`` and node i's model copy lives at index i.  The
same functions drive

* the host/single-process reference used by the paper-claims benchmarks
  (leaves are small dense arrays), and
* the distributed runtime (leaves are sharded over the mesh node axis and
  the einsum gossip lowers to cross-node collectives; see
  :mod:`repro.dist.steps`).

``grad_fn(x_stacked, key) -> g_stacked`` must return one stochastic-oracle
sample per node (Assumption 2); MC-DSGT performs its R-sample gradient
accumulation internally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import compress, driver, engine

PyTree = Any
GradFn = Callable[[PyTree, jax.Array], PyTree]


# ---------------------------------------------------------------------------
# Gossip primitives on stacked pytrees
# ---------------------------------------------------------------------------

def mix(W: jax.Array, tree: PyTree) -> PyTree:
    """z_i = sum_j W[i, j] y_j on every leaf (partial-averaging protocol)."""
    def _m(x):
        return jnp.einsum("ij,j...->i...", W.astype(x.dtype), x)
    return jax.tree.map(_m, tree)


def multi_consensus(Ws: jax.Array, tree: PyTree, *, unroll: bool = False) -> PyTree:
    """Algorithm 2: apply W^{t1}, ..., W^{t2-1} in sequence.  ``Ws`` is the
    (R, n, n) stack for the window [t1, t2).  ``unroll`` replaces the scan
    with a Python loop (cost-probe lowering)."""
    if unroll:
        out = tree
        for r in range(Ws.shape[0]):
            out = mix(Ws[r], out)
        return out
    def body(z, W):
        return mix(W, z), None
    out, _ = jax.lax.scan(body, tree, Ws)
    return out


def sun_mix(center_mask: jax.Array, delta: float, tree: PyTree) -> PyTree:
    """Structured gossip for sun-shaped graphs (beyond-paper optimization).

    For W = I - (delta/n) L(S_{n,C}) the mixing decomposes into elementwise
    ops plus two node-axis sums:

        rim i:    z_i = y_i - (d/n)(k y_i)     + (d/n) * sum_{c in C} y_c
        center c: z_c = y_c - (d/n)(n y_c)     + (d/n) * sum_{all j} y_j

    Under GSPMD the two sums lower to all-reduces of ONE parameter volume
    each — O(2 V) on the wire instead of the O(n V) all-gather the dense
    einsum needs.  Exactly equal to mix(W, tree) for sun-shaped W.

    center_mask: (n,) float 0/1; delta = n(1-beta)/ceil(n(1-beta)).
    """
    n = center_mask.shape[0]
    k = jnp.sum(center_mask)

    def _m(x):
        m = center_mask.astype(x.dtype).reshape((n,) + (1,) * (x.ndim - 1))
        kx = k.astype(x.dtype)
        St = jnp.sum(x, axis=0, keepdims=True)
        Sc = jnp.sum(x * m, axis=0, keepdims=True)
        degp = kx + (n - kx) * m
        return x - (delta / n) * (degp * x) + (delta / n) * (Sc + m * (St - Sc))

    return jax.tree.map(_m, tree)


def sun_multi_consensus(center_masks: jax.Array, delta: float, tree: PyTree,
                        *, unroll: bool = True) -> PyTree:
    """Algorithm 2 specialised to a sun-shaped schedule: apply R structured
    mixings.  center_masks: (R, n)."""
    if unroll:
        out = tree
        for r in range(center_masks.shape[0]):
            out = sun_mix(center_masks[r], delta, out)
        return out

    def body(z, mask):
        return sun_mix(mask, delta, z), None

    out, _ = jax.lax.scan(body, tree, center_masks)
    return out


def one_peer_mix(peer: jax.Array, w_peer, tree: PyTree) -> PyTree:
    """Gossip for one-peer (perfect-matching) graphs — one-peer exponential
    [42], EquiRand/random matching [32, 39]: z_i = (1-w_i) y_i + w_i y_{peer(i)}.

    ``peer`` is the (n,) matching permutation (an involution); ``w_peer`` is
    a scalar or an (n,) per-node weight vector (symmetric pairs must share a
    weight for the matrix to stay doubly stochastic).  Under GSPMD the
    node-axis take lowers to a collective-permute — O(V) point-to-point
    instead of the dense einsum's O(nV) gather (beyond-paper).
    """
    def _m(x):
        w = jnp.asarray(w_peer, x.dtype)
        if w.ndim == 1:
            w = w.reshape((w.shape[0],) + (1,) * (x.ndim - 1))
        return (1.0 - w) * x + w * jnp.take(x, peer, axis=0)
    return jax.tree.map(_m, tree)


def complete_mix(avg_weight, tree: PyTree) -> PyTree:
    """Gossip for the complete graph with W = (1-a) I + a 11^T/n:
    z = (1-a) y + a ȳ.  The node-axis mean is ONE all-reduce of one
    parameter volume — O(V) on the wire, vs the dense einsum's O(nV)."""
    def _m(x):
        a = jnp.asarray(avg_weight, x.dtype)
        return (1.0 - a) * x + a * jnp.mean(x, axis=0, keepdims=True)
    return jax.tree.map(_m, tree)


def two_level_mix(B: jax.Array, pods: int, tree: PyTree) -> PyTree:
    """Hierarchical gossip for rounds that factor across pod boundaries,
    W = B ⊗ J_p with J_p = 11^T/p the intra-pod average and B the (m, m)
    doubly-stochastic inter-pod exchange (m = n/p pods of p nodes each,
    pod-major node order — matching the ``pod|data|model`` mesh layout).

    The lowering composes the two levels instead of the dense einsum:
    intra-pod mean (ONE all-reduce of one parameter volume per pod over
    the pod-local mesh axis under GSPMD), the tiny (m, m) inter-pod
    exchange on pod means (a matching/sun-style peer exchange when B is
    structured — m is small, so the einsum volume is m·V/p of the dense
    n·V), then broadcast back within each pod.  Exactly equal to
    ``mix(kron(B, J_p), tree)``."""
    def _m(x):
        n = x.shape[0]
        m = n // pods
        xp = x.reshape((m, pods) + x.shape[1:])
        pod_mean = jnp.mean(xp, axis=1)
        mixed = jnp.einsum("ij,j...->i...", B.astype(x.dtype), pod_mean)
        out = jnp.broadcast_to(mixed[:, None], xp.shape)
        return out.reshape(x.shape)
    return jax.tree.map(_m, tree)


def sparse_mix(src: jax.Array, dst: jax.Array, w: jax.Array,
               tree: PyTree) -> PyTree:
    """Edge-list gossip in Laplacian form (see :mod:`repro.sparse.plan`):
    ``z = x + scatter_{dst} w * (x[src] - x[dst])`` on every leaf — one
    gather + scatter-add of O(edges) rows instead of the dense einsum's
    O(n^2).  The diagonal is implied (row-stochastic by construction), so
    padded edges with ``w = 0`` are exactly inert and a dropped edge's
    weight lands back on the diagonal for free (the lazy channel repair).
    """
    def _m(x):
        wx = w.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        contrib = wx * (jnp.take(x, src, axis=0) - jnp.take(x, dst, axis=0))
        return x.at[dst].add(contrib)
    return jax.tree.map(_m, tree)


def one_peer_mix_ppermute(perm: list, w_peer: float, tree: PyTree,
                          mesh, axis: str = "data") -> PyTree:
    """shard_map + lax.ppermute form of :func:`one_peer_mix` — the explicit
    point-to-point schedule (GSPMD lowers the take-based form to a full
    all-gather; this one provably emits collective-permute).

    perm: static list of (src, dst) node pairs (the matching, both
    directions).  Node axis must be fully sharded over ``axis``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _mix_shard(x):
        y = jax.lax.ppermute(x, axis, perm)
        return (1.0 - w_peer) * x + w_peer * y

    def _m(x):
        spec = P(axis, *([None] * (x.ndim - 1)))
        return shard_map(_mix_shard, mesh=mesh, in_specs=spec,
                         out_specs=spec)(x)

    return jax.tree.map(_m, tree)


# ---------------------------------------------------------------------------
# Planned gossip: consume a staged GossipPlan inside the jitted step
# ---------------------------------------------------------------------------

def make_plan_mixer(plan, *, mesh=None, axis: str = "data", mode: str | None = None,
                    dense_block=None):
    """Build ``mix_fn(tensors, t0, rounds, tree)`` applying rounds
    [t0, t0+rounds) of a :class:`repro.core.gossip.GossipPlan`.

    ``tensors`` is ``plan.tensors()`` staged on device **once** (the caller
    uploads it a single time and passes the same arrays every step — no
    per-step host transfer); ``t0`` is taken modulo the plan period.

    Two dispatch modes (default: ``plan.dispatch``, forced to ``static``
    when a mesh enables the ppermute matching path):

    * ``dynamic`` — requires a kind-uniform plan; ``t0`` may be a traced
      scalar, so ONE compilation serves every phase of the period (the
      round's parameters are gathered from the staged arrays by index);
    * ``static``  — ``t0`` must be concrete at trace time (pass it through
      ``jax.jit(..., static_argnums=...)``); each round dispatches on its
      statically-known kind, so ``empty`` rounds cost literally nothing and
      matchings may lower to an explicit ``lax.ppermute`` (``mesh`` given).
      The enclosing jit then specializes per start phase: a step consuming
      ``wps`` rounds compiles at most ``period / gcd(wps, period)`` distinct
      variants (5 for the built-in federated schedule), all within the
      first period.

    ``dense_block``: optional ``(Ws, tree) -> tree`` used for runs of
    consecutive dense rounds (e.g. the fused Pallas multi-consensus);
    defaults to the einsum scan.
    """
    P = plan.period
    kinds = plan.kinds
    has_matching = any(k == "matching" for k in kinds)
    if mode is None:
        mode = ("static" if plan.dispatch == "static"
                or (mesh is not None and has_matching) else "dynamic")
    if mode == "dynamic" and len(set(kinds)) != 1:
        raise ValueError("dynamic plan dispatch requires a kind-uniform plan; "
                         f"got {sorted(set(kinds))}")
    _dense_mc = dense_block or (lambda Ws, tr: multi_consensus(Ws, tr))

    def _apply_uniform(kind, tensors, idxs, tree):
        """Rounds ``idxs`` (all of one kind) as ONE lax.scan whose body is a
        single round: compile cost is O(1) in the window length (a Python
        loop of per-round gathers makes XLA's gather chains explode on long
        windows — one full period jitted at once is the worst case)."""
        if kind == "empty":
            return tree
        if kind == "dense":
            return _dense_mc(jnp.take(tensors["W"], idxs, axis=0), tree)
        if kind == "personalized":
            # base support only — a personalized rule's realized mix goes
            # through EngineOps.pmix (loss reweighting); plain mix() on a
            # personalized plan applies the row-stochastic prior as-is
            return _dense_mc(jnp.take(tensors["pW"], idxs, axis=0), tree)
        if kind == "two_level":
            xs = jnp.take(tensors["pod_B"], idxs, axis=0)
            body = lambda z, B: (two_level_mix(B, plan.pods, z), None)
        elif kind == "sun":
            xs = (jnp.take(tensors["center_mask"], idxs, axis=0),
                  jnp.take(tensors["delta"], idxs, axis=0))
            body = lambda z, md: (sun_mix(md[0], md[1], z), None)
        elif kind == "complete":
            xs = jnp.take(tensors["avg_w"], idxs, axis=0)
            body = lambda z, a: (complete_mix(a, z), None)
        elif kind == "sparse":
            xs = (jnp.take(tensors["esrc"], idxs, axis=0),
                  jnp.take(tensors["edst"], idxs, axis=0),
                  jnp.take(tensors["ew"], idxs, axis=0))
            body = lambda z, sdw: (sparse_mix(sdw[0], sdw[1], sdw[2], z), None)
        else:  # matching
            xs = (jnp.take(tensors["perm"], idxs, axis=0),
                  jnp.take(tensors["w_peer"], idxs, axis=0))
            body = lambda z, pw: (one_peer_mix(pw[0], pw[1], z), None)
        out, _ = jax.lax.scan(body, tree, xs)
        return out

    def _apply_static(tensors, t0, rounds, tree):
        t0 = int(t0)
        r = 0
        while r < rounds:  # group consecutive same-kind rounds
            kind = plan.rounds[(t0 + r) % P].kind
            stop = r
            while stop < rounds and plan.rounds[(t0 + stop) % P].kind == kind:
                stop += 1
            idx_list = [(t0 + q) % P for q in range(r, stop)]
            if kind == "matching" and mesh is not None:
                # explicit point-to-point schedule: perm is static here, so
                # each round lowers to a collective-permute
                for idx in idx_list:
                    rd = plan.rounds[idx]
                    if np.allclose(rd.w_peer, rd.w_peer[0]):
                        pairs = [(i, int(p)) for i, p in enumerate(rd.perm)]
                        tree = one_peer_mix_ppermute(
                            pairs, float(rd.w_peer[0]), tree, mesh, axis)
                    else:
                        tree = one_peer_mix(jnp.asarray(rd.perm),
                                            jnp.asarray(rd.w_peer), tree)
            elif kind != "empty":
                tree = _apply_uniform(kind, tensors, jnp.asarray(idx_list),
                                      tree)
            r = stop
        return tree

    def _apply_dynamic(tensors, t0, rounds, tree):
        idxs = (t0 + jnp.arange(rounds)) % P
        return _apply_uniform(kinds[0], tensors, idxs, tree)

    fn = _apply_static if mode == "static" else _apply_dynamic
    fn.dispatch = mode
    return fn


def node_mean(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=True), tree)


def broadcast_nodes(tree: PyTree, n: int) -> PyTree:
    """Stack n identical copies of an (unstacked) pytree."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)


# Shared pytree arithmetic lives in the engine (single source); re-exported
# here for the runtimes and tests that import it from this module.
_axpy = engine._axpy
_accumulate = engine._accumulate


# ---------------------------------------------------------------------------
# Algorithm interfaces (thin adapters over repro.core.engine)
# ---------------------------------------------------------------------------

class AlgoState(NamedTuple):
    x: PyTree            # stacked model copies
    h: Optional[PyTree]  # gradient tracker (None for DSGD), x^{k-1} for D^2
    g_prev: Optional[PyTree]
    opt_state: Any
    k: jax.Array         # round counter
    res: Optional[tuple] = None  # compressed-gossip EF residuals (x, h)
    buf: Optional[tuple] = None  # stale-payload queues (x, h) when delay>0


@dataclasses.dataclass(frozen=True)
class DecentralizedAlgorithm:
    """A decentralized optimizer: ``weights`` passed to ``step`` is the
    (rounds, n, n) stack of gossip matrices this round consumes (rounds =
    ``weights_per_step``).  Built from an :class:`repro.core.engine`
    UpdateRule by :func:`from_rule` — the update arithmetic itself lives in
    the engine, shared with the distributed runtime."""

    name: str
    weights_per_step: int
    init: Callable[[PyTree], AlgoState]
    step: Callable[[AlgoState, GradFn, jax.Array, jax.Array], AlgoState]
    warm: Callable[[AlgoState, GradFn, jax.Array], AlgoState] = None
    rule: "engine.UpdateRule" = None
    local_opt: Any = None


def from_rule(rule: engine.UpdateRule, local_opt=None) -> DecentralizedAlgorithm:
    """Bind an UpdateRule to the host reference runtime: the stacked-einsum
    multi-consensus mixer and a ``grad_fn(x, key)`` oracle closure."""
    if local_opt is not None and not rule.supports_local_opt:
        raise ValueError(f"algo {rule.name!r} does not support a local "
                         "optimizer hook")

    def _ops(grad_fn, weights, key):
        cmix = None
        if rule.compression is not None:
            cmix = compress.make_compressed_mixer(
                lambda idx, m: mix(weights[idx], m), rule.compression)
        grad = lambda x: (None, engine._accumulate(grad_fn, x, key, rule.R))
        pmix = None
        if rule.personalized:
            # personalized oracle contract: grad_fn(x, key) -> (losses, g)
            # with losses the per-node (n,) loss vector of the sample — the
            # similarity signal pmix reweights the base rows with in-jit.
            grad = lambda x: grad_fn(x, key)
            pmix = lambda off, r, tree, losses: multi_consensus(
                engine.personalized_weights(weights[off:off + r], losses,
                                            rule.tau), tree)
        return engine.EngineOps(
            mix=lambda off, r, tree: multi_consensus(
                weights[off:off + r], tree),
            grad=grad,
            local_update=(local_opt.update if local_opt
                          else (lambda g, s: (g, s))),
            cast_aux=lambda tree: tree,
            cmix=cmix,
            pmix=pmix)

    def _to_engine(s: AlgoState) -> engine.EngineState:
        return engine.EngineState(s.x, s.h, s.g_prev, s.opt_state, s.k,
                                  s.res, s.buf)

    def _to_algo(s: engine.EngineState) -> AlgoState:
        return AlgoState(s.x, s.h, s.g_prev, s.opt, s.k, s.res, s.buf)

    def init(x0: PyTree) -> AlgoState:
        return _to_algo(engine.init_state(
            rule, x0, opt_init=local_opt.init if local_opt else None))

    def step(state: AlgoState, grad_fn: GradFn, weights: jax.Array,
             key: jax.Array, obs: tuple = ()) -> AlgoState:
        """One round; with ``obs`` metric names (repro.obs), returns
        ``(state, obs_dict)`` — the engine's in-jit scalars."""
        es, aux = engine.step(rule, _to_engine(state),
                              _ops(grad_fn, weights, key), obs=obs)
        if obs:
            return _to_algo(es), aux[1]
        return _to_algo(es)

    def warm(state: AlgoState, grad_fn: GradFn, key: jax.Array) -> AlgoState:
        return _to_algo(engine.warm_start(rule, _to_engine(state),
                                          _ops(grad_fn, None, key)))

    return DecentralizedAlgorithm(rule.name, rule.weights_per_step, init,
                                  step, warm, rule, local_opt)


def plan_step(algo: DecentralizedAlgorithm, plan, *, mesh=None,
              axis: str = "data"):
    """Bind ``algo``'s update rule to a staged :class:`repro.core.gossip.
    GossipPlan` — the host-runtime analogue of ``dist.steps``'
    ``gossip_impl='auto'``.  Returns ``step(state, grad_fn, tensors, t,
    key)`` where ``tensors`` is the plan staged on device once
    (:func:`repro.core.driver.stage_plan`) and ``t`` the start round
    (concrete at trace time when ``step.dispatch == 'static'``).  Realized
    post-fault schedules (:mod:`repro.sim`) ride this path too: degraded
    matchings take the one-peer lowering and fully dropped (``empty``)
    rounds cost nothing."""
    rule = algo.rule
    if rule is None:
        raise ValueError("plan_step requires an engine-rule algorithm "
                         "(built via from_rule)")
    # Edge-list plans (repro.sparse.SparseGossipPlan) carry their own mixer
    # factory with the same mix_fn contract — duck-typed so the core stays
    # import-free of the sparse subsystem.
    if hasattr(plan, "make_mixer"):
        mixer = plan.make_mixer(mesh=mesh, axis=axis)
    else:
        mixer = make_plan_mixer(plan, mesh=mesh, axis=axis)
    local_update = (algo.local_opt.update if algo.local_opt is not None
                    else (lambda g, s: (g, s)))

    def pstep(state: AlgoState, grad_fn: GradFn, tensors, t,
              key: jax.Array, obs: tuple = ()) -> AlgoState:
        cmix = None
        if rule.compression is not None:
            cmix = compress.make_compressed_mixer(
                lambda idx, m: mixer(tensors, t + idx, 1, m),
                rule.compression)
        grad = lambda x: (None, engine._accumulate(grad_fn, x, key, rule.R))
        pmix = None
        if rule.personalized:
            # staged per-node base rows ("pW", never a dense fallback) are
            # reweighted in-jit by this step's per-node losses; same oracle
            # contract as from_rule: grad_fn(x, key) -> (losses, g)
            grad = lambda x: grad_fn(x, key)

            def pmix(off, r, tree, losses):
                idxs = (t + off + jnp.arange(r)) % plan.period
                Ws = engine.personalized_weights(
                    jnp.take(tensors["pW"], idxs, axis=0), losses, rule.tau)
                return multi_consensus(Ws, tree)
        ops = engine.EngineOps(
            mix=lambda off, r, tree: mixer(tensors, t + off, r, tree),
            grad=grad,
            local_update=local_update,
            cast_aux=lambda tree: tree,
            cmix=cmix,
            pmix=pmix)
        es, aux = engine.step(rule, engine.EngineState(
            state.x, state.h, state.g_prev, state.opt_state, state.k,
            state.res, state.buf), ops, obs=obs)
        new = AlgoState(es.x, es.h, es.g_prev, es.opt, es.k, es.res, es.buf)
        return (new, aux[1]) if obs else new

    pstep.dispatch = mixer.dispatch
    return pstep


# -- The paper's rules + the federated/local-update family, one line each. --

def dsgd(gamma: float, local_opt=None) -> DecentralizedAlgorithm:
    """DSGD [12]: x^{k+1} = W^k (x^k - gamma * g^k)."""
    return from_rule(engine.make_rule("dsgd", gamma), local_opt)


def dsgt(gamma: float) -> DecentralizedAlgorithm:
    """DSGT [40]: x^{k+1} = W (x^k - gamma h^k);
    h^{k+1} = W (h^k + g^{k+1} - g^k).  Two gossip rounds per step."""
    return from_rule(engine.make_rule("dsgt", gamma))


def mc_dsgt(gamma: float, R: int) -> DecentralizedAlgorithm:
    """Multi-Consensus DSGT (Algorithm 1): R-sample gradient accumulation
    and R gossip rounds per consensus phase; ``weights`` is the (2R, n, n)
    stack [W^{2kR}, ..., W^{(2k+2)R - 1}] (first R mix x, last R mix h)."""
    return from_rule(engine.make_rule("mc_dsgt", gamma, R=R))


def d2(gamma: float) -> DecentralizedAlgorithm:
    """D^2 [35]: x^{k+1} = W(2 x^k - x^{k-1} - gamma (g^k - g^{k-1})).
    Requires symmetric PSD W (the Theorem 3 matrices qualify)."""
    return from_rule(engine.make_rule("d2", gamma))


def local_sgd(gamma: float, local_opt=None) -> DecentralizedAlgorithm:
    """Local SGD / FedAvg as an update rule: x^{k+1} = W^k x^k - gamma g^k
    with the oracle queried at the mixed iterate.  Over a federated
    schedule, ``empty`` rounds make this a pure local step and the
    periodic ``complete`` round is the global average (paper §1)."""
    return from_rule(engine.make_rule("local_sgd", gamma), local_opt)


def personalized(gamma: float, tau: float = 4.0,
                 local_opt=None) -> DecentralizedAlgorithm:
    """Dada-style personalized neighbor averaging: x ← P(ℓ)(x − γ g) with
    P(ℓ) the loss-proximity reweighting of the round's support
    (:func:`repro.core.engine.personalized_weights`).  The fleet converges
    to n personalized models, not one consensus model; ``grad_fn`` must
    return ``(per-node losses, grads)``."""
    return from_rule(engine.make_rule("personalized", gamma, tau=tau),
                     local_opt)


def gt_local(gamma: float, local_opt=None) -> DecentralizedAlgorithm:
    """Gradient tracking with local updates (DIGing-style placement):
    x^{k+1} = W^k x^k - gamma h^k;  h^{k+1} = W^k h^k + g^{k+1} - g^k.
    x and h share ONE gossip round per step and the tracker correction
    stays local, so the tracker keeps tracking through empty (local-only)
    rounds of a federated schedule."""
    return from_rule(engine.make_rule("gt_local", gamma), local_opt)


def warm_start(algo: DecentralizedAlgorithm, state: AlgoState,
               grad_fn: GradFn, key: jax.Array) -> AlgoState:
    """Tracker/correction initialization (Algorithm 1's h^0 for the
    tracking rules; x^{-1}/g^{-1} for D^2) — delegates to the engine."""
    return algo.warm(state, grad_fn, key)


# ---------------------------------------------------------------------------
# Driver (delegates to the unified repro.core.driver loop)
# ---------------------------------------------------------------------------

def run(algo: DecentralizedAlgorithm, x0: PyTree, grad_fn: GradFn,
        weight_schedule, num_steps: int, key: jax.Array,
        eval_fn: Optional[Callable[[PyTree], Any]] = None,
        eval_every: int = 1, gossip_impl: str = "dense", telemetry=None,
        obs: tuple = (), tracer=None):
    """Host-side training loop over a :class:`repro.core.gossip.WeightSchedule`.

    The schedule is staged on device ONCE up front — one period (or, for
    aperiodic schedules, the whole run's window) of matrices — and the
    jitted step gathers its ``weights_per_step`` rounds from the staged
    stack by index: no per-step host ``stacked()`` + transfer.  The
    staging, loop, and history recording are the shared
    :mod:`repro.core.driver` (same code path as the distributed CLI).

    Returns (final_state, history) where history records ``eval_fn`` of the
    node-mean model x-bar every ``eval_every`` rounds, keyed by the total
    gossip/oracle budget T = k * weights_per_step consumed so far (the
    paper's x-axis in Figure 2).
    """
    return driver.run_algorithm(algo, x0, grad_fn, weight_schedule,
                                num_steps, key, eval_fn=eval_fn,
                                eval_every=eval_every,
                                gossip_impl=gossip_impl, telemetry=telemetry,
                                obs=obs, tracer=tracer)
