"""Single-source compressed-gossip support (the spec's ``compression`` axis).

Communication — not compute — is the scarce resource in the paper's
regime, and this module makes the wire format a first-class scenario knob:
group-wise 1-bit (``sign``) or ``int8`` quantization of every gossip
payload with per-node error-feedback residuals (the Bagua
low-precision-decentralized construction), applied per realized round
inside the step's mix window.

Like :mod:`repro.core.engine` for the update arithmetic, everything here
is runtime-neutral and exists exactly once:

* :class:`CompressionConfig` — the frozen runtime config an
  :class:`repro.exp.spec.CompressionSpec` lowers to;
* :func:`flatten_grouped` / :func:`unflatten_grouped` — stacked pytree
  <-> (n, D) f32 matrix with every leaf padded to a multiple of ``group``,
  so quantization groups never straddle leaves and any block size the
  fused kernel picks sees the same group boundaries (zero padding is a
  fixed point of quantize/mix/residual, so the transform is exact);
* :func:`make_compressed_mixer` — wraps ANY per-round mixer (host einsum,
  sun rewrite, staged plan dispatch, dense dist) into the error-feedback
  compressed window ``cmix(offset, rounds, tree, res, on)``;
* :func:`payload_bytes` — the bytes-per-round accounting used by
  ``sim.telemetry``, the manifests, and ``bench_compression``.

The quantization math itself lives in
:func:`repro.kernels.ref.quantize_dequantize_ref` (shared verbatim with
the fused Pallas kernel).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kernels_ref

PyTree = Any

SCHEMES = ("none", "sign", "int8")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Runtime compression config.  ``scheme``: 'sign' (1 bit/entry) or
    'int8'; ``error_feedback``: carry the per-node quantization error into
    the next round's payload; ``warmup``: driver steps that gossip at full
    precision before the scheme activates (the Bagua warm-start idiom —
    early training is most sensitive to compression noise); ``group``:
    entries per quantization scale."""

    scheme: str = "sign"
    error_feedback: bool = True
    warmup: int = 0
    group: int = 256

    def __post_init__(self):
        if self.scheme not in SCHEMES[1:]:
            raise ValueError(f"CompressionConfig.scheme={self.scheme!r}: "
                             f"must be one of {SCHEMES[1:]} ('none' means "
                             "no config at all)")
        if self.group < 1:
            raise ValueError(f"group={self.group}: must be >= 1")
        if self.warmup < 0:
            raise ValueError(f"warmup={self.warmup}: must be >= 0")


def payload_bytes(dim: int, scheme: str, group: int = 256) -> int:
    """Nominal bytes ONE node transmits in ONE realized gossip round for a
    ``dim``-entry state: the quantized entries plus one f32 scale per
    group ('none' = full f32, the baseline denominator)."""
    if scheme == "none":
        return 4 * dim
    groups = math.ceil(dim / group)
    if scheme == "sign":
        return math.ceil(dim / 8) + 4 * groups
    if scheme == "int8":
        return dim + 4 * groups
    raise ValueError(f"unknown compression scheme {scheme!r} "
                     f"(have {SCHEMES})")


# ---------------------------------------------------------------------------
# Stacked pytree <-> group-aligned (n, D) matrix
# ---------------------------------------------------------------------------

def flatten_grouped(tree: PyTree, group: int):
    """Flatten a node-stacked pytree into one f32 (n, D) matrix with every
    leaf zero-padded to a multiple of ``group``; returns (matrix, meta)."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    cols, infos = [], []
    for leaf in leaves:
        flat = leaf.reshape(n, -1).astype(jnp.float32)
        size = flat.shape[1]
        pad = (-size) % group
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        cols.append(flat)
        infos.append((leaf.shape, leaf.dtype, size + pad))
    return jnp.concatenate(cols, axis=1), (treedef, infos)


def unflatten_grouped(mat: jax.Array, meta) -> PyTree:
    treedef, infos = meta
    out, off = [], 0
    for shape, dtype, padded in infos:
        size = math.prod(shape[1:]) if len(shape) > 1 else 1
        out.append(mat[:, off:off + size].reshape(shape).astype(dtype))
        off += padded
    return jax.tree.unflatten(treedef, out)


def quantize_dequantize(buf: jax.Array, *, scheme: str,
                        group: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(dequantized payload, quantization error) of an (n, D) matrix with
    D % group == 0 — the shared oracle math (see kernels/ref.py)."""
    return kernels_ref.quantize_dequantize_ref(buf, scheme=scheme,
                                               group=group)


# ---------------------------------------------------------------------------
# The generic compressed window mixer
# ---------------------------------------------------------------------------

def make_compressed_mixer(mix_round: Callable[[int, jax.Array], jax.Array],
                          cfg: CompressionConfig):
    """Lift a per-round matrix mixer into the error-feedback compressed
    window ``cmix(offset, rounds, tree, res, on) -> (tree, res)``.

    ``mix_round(idx, mat)`` applies ONE gossip round (window-relative index
    ``idx`` = offset + r) to an (n, D) matrix — a single-leaf pytree, so
    every existing mixer (stacked einsum, sun rewrite, plan dispatch,
    ppermute matching) works unchanged.  ``res`` is the per-node residual
    pytree (same structure as the state); ``on`` is the warmup gate: a
    traced bool selecting compressed vs full-precision rounds, or None
    when no warmup is configured (the cond is elided entirely).
    """

    def cmix(offset: int, rounds: int, tree: PyTree, res: PyTree,
             on: Optional[jax.Array]):
        mat, meta = flatten_grouped(tree, cfg.group)
        rmat, rmeta = flatten_grouped(res, cfg.group)

        def compressed(mat, rmat):
            for r in range(rounds):
                buf = mat + rmat
                deq, err = quantize_dequantize(buf, scheme=cfg.scheme,
                                               group=cfg.group)
                if cfg.error_feedback:
                    rmat = err
                mat = mix_round(offset + r, deq)
            return mat, rmat

        def plain(mat, rmat):
            for r in range(rounds):
                mat = mix_round(offset + r, mat)
            return mat, rmat

        if on is None:
            mat, rmat = compressed(mat, rmat)
        else:
            mat, rmat = jax.lax.cond(on, compressed, plain, mat, rmat)
        return unflatten_grouped(mat, meta), unflatten_grouped(rmat, rmeta)

    return cmix


def init_residual(x0: PyTree, uses_tracker: bool,
                  dtype=None) -> Tuple[PyTree, Optional[PyTree]]:
    """Zeroed (res_x, res_h) error-feedback state matching ``x0``'s
    structure (``res_h`` only for tracking rules — the tracker stream
    gossips too and carries its own residual)."""
    def zeros():
        return jax.tree.map(
            lambda l: jnp.zeros(l.shape, dtype or l.dtype), x0)
    return (zeros(), zeros() if uses_tracker else None)
