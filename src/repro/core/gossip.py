"""Gossip weight matrices and multi-consensus (paper §2 Assumption 3, Alg. 2).

Weight-matrix schedules are host-side numpy objects (tiny, n <= 64); the
values are fed into jitted distributed steps as regular array arguments so a
single compiled step serves the whole time-varying schedule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from . import topology as topo

WeightMatrix = np.ndarray  # (n, n) float64
MatrixSchedule = Callable[[int], WeightMatrix]


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def graph_laplacian(adj: topo.Adjacency) -> np.ndarray:
    a = adj.copy().astype(float)
    np.fill_diagonal(a, 0.0)
    deg = a.sum(axis=1)
    return np.diag(deg) - a


def laplacian_weights(adj: topo.Adjacency, delta_over_n: float) -> WeightMatrix:
    """W = I - (delta/n) * L(G) — the Theorem 3 rule (with delta_over_n =
    delta/n) and, with delta_over_n = 1/d_max, the classic Laplacian rule of
    Remark 5."""
    n = adj.shape[0]
    return np.eye(n) - delta_over_n * graph_laplacian(adj)


def laplacian_rule(adj: topo.Adjacency) -> WeightMatrix:
    """W = I - L / d_max (Remark 5)."""
    L = graph_laplacian(adj)
    dmax = float(np.max(np.diag(L)))
    if dmax == 0:
        return np.eye(adj.shape[0])
    return np.eye(adj.shape[0]) - L / dmax


def metropolis_weights(adj: topo.Adjacency) -> WeightMatrix:
    """Metropolis-Hastings doubly-stochastic weights for an undirected graph."""
    n = adj.shape[0]
    a = adj.copy()
    np.fill_diagonal(a, False)
    deg = a.sum(axis=1)
    W = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if a[i, j]:
                W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i, i] = 1.0 - W[i].sum()
    return W


def mixing_beta(W: WeightMatrix) -> float:
    """beta = ||W - (1/n) 11^T||_2 (Assumption 3.3)."""
    n = W.shape[0]
    return float(np.linalg.norm(W - np.ones((n, n)) / n, ord=2))


def check_assumption3(W: WeightMatrix, adj: topo.Adjacency | None = None,
                      beta: float | None = None, atol: float = 1e-9) -> None:
    """Raise AssertionError unless W satisfies Assumption 3 (sparsity pattern,
    double stochasticity, spectral bound)."""
    n = W.shape[0]
    ones = np.ones(n)
    if adj is not None:
        off = ~adj & ~np.eye(n, dtype=bool)
        assert np.allclose(W[off], 0.0, atol=atol), "W has weight on inactive links"
    assert np.allclose(W @ ones, ones, atol=atol), "W 1 != 1 (row sums)"
    assert np.allclose(ones @ W, ones, atol=atol), "1^T W != 1^T (col sums)"
    b = mixing_beta(W)
    if beta is not None:
        assert b <= beta + 1e-7, f"beta(W)={b} exceeds required {beta}"
    assert b <= 1.0 + 1e-9, f"beta(W)={b} > 1"


# ---------------------------------------------------------------------------
# GossipPlan: per-round structured lowerings (the planning layer)
# ---------------------------------------------------------------------------

# Threshold policy for the automatic sparse lowering (``sparse="auto"``):
# a round that no structured lowering accepts is kept as an edge list
# instead of a dense matrix when the network is large AND the round is
# actually sparse.  Below the node floor the dense einsum is cheap and the
# historical lowering stays bit-exact; above it, a low-density round costs
# O(edges) instead of O(n^2) per mix (see README "Sparse plans & client
# sampling").
SPARSE_MIN_NODES = 128
SPARSE_MAX_DENSITY = 0.25


@dataclasses.dataclass(frozen=True)
class GossipRound:
    """One round of a :class:`GossipPlan`: the dense matrix plus, when the
    round is structured, the parameters of its cheap lowering.

    kind → lowering (see :mod:`repro.core.algorithms`):

    * ``empty``     — z = x (no-op; ``perm`` = identity, ``w_peer`` = 0);
    * ``matching``  — :func:`one_peer_mix`: z_i = (1-w_i) x_i + w_i x_{perm(i)};
    * ``sun``       — :func:`sun_mix` with W = I - (delta/n) L(S_{n,C});
    * ``complete``  — :func:`complete_mix`: z = (1-a) x + a x̄;
    * ``two_level`` — :func:`two_level_mix`: W = B ⊗ J_p factors into an
      intra-pod average (p nodes/pod, one allreduce per pod) composed with
      the (m, m) inter-pod exchange ``pod_B`` on pod means;
    * ``sparse``    — :func:`repro.core.algorithms.sparse_mix`: COO edge
      scatter in Laplacian form, z = x + Σ_e w_e (x_src - x_dst) → dst
      (diagonal implied by row-stochasticity; see :mod:`repro.sparse.plan`);
    * ``personalized`` — per-node weight rows staged as-is: the round's
      base support/weights, row-stochastic only (NOT Assumption 3), whose
      rows the personalized engine reweights in-jit by loss-proximity
      similarity (:func:`repro.core.engine.personalized_weights`) before
      mixing.  Kept first-class so non-uniform, data-dependent weights are
      a real plan path instead of a silent dense fallback;
    * ``dense``     — generic mix(W, ·) einsum.  A dense round that only
      got here because every cheaper lowering was rejected carries
      ``fallback_reason`` naming why (surfaced per window as the
      ``dense_fallback`` count in :mod:`repro.sim.telemetry`).
    """

    kind: str
    W: np.ndarray                              # (n, n) dense reference
    center_mask: np.ndarray | None = None      # (n,) float32, sun
    delta: float | None = None                 # sun: W = I - (delta/n) L
    perm: np.ndarray | None = None             # (n,) int32, matching/empty
    w_peer: np.ndarray | None = None           # (n,) float32, matching/empty
    avg_weight: float | None = None            # complete: z = (1-a) x + a x̄
    pod_B: np.ndarray | None = None            # (m, m) inter-pod, two_level
    pods: int | None = None                    # p = nodes per pod, two_level
    edge_src: np.ndarray | None = None         # (E,) int32, sparse
    edge_dst: np.ndarray | None = None         # (E,) int32, sparse
    edge_w: np.ndarray | None = None           # (E,) float64, sparse
    fallback_reason: str | None = None         # dense: why lowerings skipped

    @property
    def n(self) -> int:
        return self.W.shape[0]

    def as_dense(self) -> np.ndarray:
        """Reconstruct the dense matrix implied by the structured lowering
        (== ``W`` for a valid plan; the planner asserts this)."""
        n = self.n
        if self.kind == "empty":
            return np.eye(n)
        if self.kind == "complete":
            a = self.avg_weight
            return (1.0 - a) * np.eye(n) + a * np.ones((n, n)) / n
        if self.kind == "matching":
            W = np.diag(1.0 - self.w_peer.astype(np.float64))
            W[np.arange(n), self.perm] += self.w_peer
            return W
        if self.kind == "sun":
            adj = topo.sun_shaped_graph(n, np.flatnonzero(self.center_mask))
            return laplacian_weights(adj, self.delta / n)
        if self.kind == "two_level":
            p = self.pods
            return np.kron(np.asarray(self.pod_B, np.float64),
                           np.ones((p, p)) / p)
        if self.kind == "sparse":
            W = np.zeros((n, n))
            W[self.edge_dst, self.edge_src] = self.edge_w
            rowsum = np.bincount(self.edge_dst, weights=self.edge_w,
                                 minlength=n)
            W[np.arange(n), np.arange(n)] = 1.0 - rowsum
            return W
        return np.asarray(self.W, np.float64)


def plan_round(W: WeightMatrix,
               structure: "topo.RoundStructure | None" = None,
               atol: float = 1e-9, pods: int | None = None,
               sparse: "bool | str" = "auto",
               personalized: bool = False) -> GossipRound:
    """Lower one weight matrix to its cheapest structured form.

    ``structure`` is the topology-level tag when the schedule declares one;
    otherwise the sparsity pattern of ``W`` is classified.  The structured
    parameters are extracted from ``W`` and accepted only if they reproduce
    ``W`` exactly (within ``atol``); any mismatch — e.g. non-uniform weights
    on a sun graph — falls back to the always-correct dense lowering.

    ``pods`` (p nodes per pod, pod-major order — the ``pod|data|model``
    mesh layout) enables the hierarchical fallback: a round none of the
    flat lowerings accept is tested for the two-level factorization
    W = B ⊗ J_p and, when it factors exactly across pod boundaries,
    lowered to ``two_level`` instead of dense.

    ``sparse`` controls the edge-list fallback for rounds no structured
    (or hierarchical) lowering accepts: ``"auto"`` (default) keeps such a
    round as COO edges instead of a dense matrix when
    ``n >= SPARSE_MIN_NODES`` and its off-diagonal density is at most
    ``SPARSE_MAX_DENSITY`` — below the threshold the historical dense
    lowering is bit-exact-preserved; ``True``/``False`` force/disable the
    sparse path regardless of size (tests use ``True`` for small-n
    equivalence).

    ``personalized`` marks the round as the base support of a personalized
    (loss-proximity reweighted) rule: the row-stochastic ``W`` is staged
    as-is under ``kind="personalized"`` — its n per-node weight rows are
    the similarity prior the engine renormalizes in-jit — instead of being
    classified.  This is never a dense fallback: the weights are
    data-dependent at run time, so no static structured lowering can
    reproduce the realized mix.
    """
    W = np.asarray(W, np.float64)
    n = W.shape[0]
    if personalized:
        assert np.allclose(W.sum(axis=1), 1.0, atol=1e-6), \
            "personalized base weights must be row-stochastic"
        return GossipRound("personalized", W)
    if n == 1:  # single node: any valid W is [[1]] — no communication
        rd = GossipRound("empty", W, perm=np.zeros(1, np.int32),
                         w_peer=np.zeros(1, np.float32))
        return rd if np.allclose(W, 1.0) else GossipRound(
            "dense", W, fallback_reason="single-node matrix is not [[1]]")
    if structure is None or structure.kind == "dense":
        adj = np.abs(W) > atol
        np.fill_diagonal(adj, True)
        structure = topo.classify_adjacency(adj)
    eye = np.eye(n)

    def _accept(rd: GossipRound) -> GossipRound | None:
        return rd if np.allclose(rd.as_dense(), W, atol=1e-8) else None

    rd = None
    if structure.kind == "empty":
        rd = _accept(GossipRound(
            "empty", W, perm=np.arange(n, dtype=np.int32),
            w_peer=np.zeros(n, np.float32)))
    elif structure.kind == "complete":
        a = float(W[~eye.astype(bool)].mean() * n)
        rd = _accept(GossipRound("complete", W, avg_weight=a))
    elif structure.kind == "matching":
        perm = np.asarray(structure.perm, np.int32)
        idx = np.arange(n)
        # fixed points (unmatched nodes of a partial matching) exchange
        # nothing: their peer weight is 0, not the diagonal entry
        w = np.where(perm == idx, 0.0, W[idx, perm]).astype(np.float32)
        rd = _accept(GossipRound("matching", W, perm=perm, w_peer=w))
    elif structure.kind == "sun":
        center = np.asarray(structure.center, int)
        mask = np.zeros(n, np.float32)
        mask[center] = 1.0
        rim = np.setdiff1d(np.arange(n), center)
        probe = rim[0] if rim.size else 1  # any edge weight; all must agree
        delta = float(W[probe, center[0]] * n)
        rd = _accept(GossipRound("sun", W, center_mask=mask, delta=delta))
    if rd is None and pods is not None and 1 < pods < n and n % pods == 0:
        # hierarchical fallback: does the round factor as B ⊗ J_p?  Each
        # p×p block of W must be constant (= B[I,J]/p); the block means
        # give the candidate B and _accept checks the exact kron.
        B = W.reshape(n // pods, pods, n // pods, pods).mean(axis=(1, 3)) * pods
        rd = _accept(GossipRound("two_level", W, pod_B=B, pods=pods))
    if rd is None and sparse is not False:
        off = np.abs(W) > atol
        np.fill_diagonal(off, False)
        nnz = int(off.sum())
        density = nnz / max(1, n * (n - 1))
        if sparse is True or (n >= SPARSE_MIN_NODES
                              and density <= SPARSE_MAX_DENSITY):
            dst, src = np.nonzero(off)
            rd = _accept(GossipRound(
                "sparse", W, edge_src=src.astype(np.int32),
                edge_dst=dst.astype(np.int32), edge_w=W[dst, src]))
    if rd is not None:
        return rd
    # Every cheaper lowering was rejected: fall back to the dense einsum,
    # but say why — callers surface this per window (sim.telemetry's
    # dense_fallback count) instead of silently paying O(n^2) per mix.
    rows_ok = np.allclose(W.sum(axis=1), 1.0, atol=1e-6)
    cols_ok = np.allclose(W.sum(axis=0), 1.0, atol=1e-6)
    if rows_ok and not cols_ok:
        reason = ("row-stochastic-only weights (outside Assumption 3); "
                  "plan with personalized=True to stage per-node rows")
    elif structure.kind in ("empty", "complete", "matching", "sun"):
        reason = f"non-uniform weights on {structure.kind} support"
    elif n < SPARSE_MIN_NODES:
        reason = (f"unstructured round below the sparse floor "
                  f"(n={n} < {SPARSE_MIN_NODES})")
    else:
        reason = "unstructured round too dense for the edge-list lowering"
    return GossipRound("dense", W, fallback_reason=reason)


@dataclasses.dataclass(frozen=True)
class GossipPlan:
    """A window of structured gossip rounds, device-stageable in one shot.

    ``tensors()`` packs every round's lowering parameters into dense
    ``(period, ...)`` arrays; drivers upload them **once** and the jitted
    step indexes them by ``t % period`` (see
    :func:`repro.core.algorithms.make_plan_mixer`) — no per-step host
    re-stacking or transfer."""

    rounds: tuple  # tuple[GossipRound]

    @property
    def period(self) -> int:
        return len(self.rounds)

    @property
    def n(self) -> int:
        return self.rounds[0].n

    @property
    def kinds(self) -> tuple:
        return tuple(r.kind for r in self.rounds)

    @property
    def pods(self) -> int | None:
        """Pod size p shared by the plan's ``two_level`` rounds (None when
        the plan has none).  Mixed pod sizes in one plan are rejected —
        the mixer bakes p in statically."""
        ps = {r.pods for r in self.rounds if r.kind == "two_level"}
        if not ps:
            return None
        if len(ps) != 1:
            raise ValueError(f"two_level rounds disagree on pod size: {ps}")
        return ps.pop()

    @property
    def dispatch(self) -> str:
        """'dynamic' when one lowering serves every round (a single
        compilation with a traced round index), else 'static' (the step
        specializes per start phase; empty rounds then cost nothing)."""
        return "dynamic" if len(set(self.kinds)) == 1 else "static"

    def tensors(self) -> dict:
        """Device-stageable plan arrays, keyed by lowering family.  Rounds
        of other kinds hold identity defaults at their index (unused)."""
        P, n = self.period, self.n
        kinds = set(self.kinds)
        out = {}
        if "dense" in kinds:
            out["W"] = np.stack([r.W for r in self.rounds]).astype(np.float32)
        if "personalized" in kinds:
            # n per-node base weight rows per round, staged once; the engine
            # reweights + renormalizes the rows in-jit from this step's
            # per-node losses (engine.personalized_weights).
            out["pW"] = np.stack(
                [r.W if r.kind == "personalized" else np.eye(n)
                 for r in self.rounds]).astype(np.float32)
        if "sun" in kinds:
            out["center_mask"] = np.stack(
                [r.center_mask if r.kind == "sun" else np.zeros(n, np.float32)
                 for r in self.rounds])
            out["delta"] = np.asarray(
                [r.delta if r.kind == "sun" else 0.0 for r in self.rounds],
                np.float32)
        if kinds & {"matching", "empty"}:
            ident = np.arange(n, dtype=np.int32)
            out["perm"] = np.stack(
                [r.perm if r.perm is not None else ident
                 for r in self.rounds])
            out["w_peer"] = np.stack(
                [r.w_peer if r.w_peer is not None else np.zeros(n, np.float32)
                 for r in self.rounds])
        if "complete" in kinds:
            out["avg_w"] = np.asarray(
                [r.avg_weight if r.kind == "complete" else 0.0
                 for r in self.rounds], np.float32)
        if "two_level" in kinds:
            m = n // self.pods
            out["pod_B"] = np.stack(
                [r.pod_B if r.kind == "two_level" else np.eye(m)
                 for r in self.rounds]).astype(np.float32)
        if "sparse" in kinds:
            # per-round edge arrays padded to the widest round; pad edges
            # carry w = 0, so they contribute exactly nothing to the mix
            emax = max(1, max(r.edge_src.size for r in self.rounds
                              if r.kind == "sparse"))
            esrc = np.zeros((P, emax), np.int32)
            edst = np.zeros((P, emax), np.int32)
            ew = np.zeros((P, emax), np.float32)
            for i, r in enumerate(self.rounds):
                if r.kind == "sparse":
                    e = r.edge_src.size
                    esrc[i, :e] = r.edge_src
                    edst[i, :e] = r.edge_dst
                    ew[i, :e] = r.edge_w
            out.update(esrc=esrc, edst=edst, ew=ew)
        return out

    def validate(self) -> None:
        """Assert every structured lowering equals its dense matrix and is a
        valid gossip matrix (Assumption 3).  ``personalized`` rounds live
        outside Assumption 3 by design (row-stochastic only, column sums
        free) — they are checked for row-stochasticity instead."""
        for t, rd in enumerate(self.rounds):
            rec = rd.as_dense()
            assert np.allclose(rec, rd.W, atol=1e-8), \
                f"round {t}: {rd.kind} lowering != dense matrix"
            if rd.kind == "personalized":
                n = rd.n
                assert np.allclose(rec @ np.ones(n), np.ones(n), atol=1e-6), \
                    f"round {t}: personalized base weights not row-stochastic"
            else:
                check_assumption3(rec)


# ---------------------------------------------------------------------------
# Matrix schedules built from topology schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeightSchedule:
    """A periodic sequence of weight matrices W^t, optionally annotated with
    the topology-level :class:`repro.core.topology.RoundStructure` of each
    round (attached by :func:`schedule_from_topology`; the planner falls
    back to sparsity classification when absent)."""

    matrices: tuple  # tuple[np.ndarray]
    structures: tuple | None = None  # tuple[RoundStructure] | None

    @property
    def n(self) -> int:
        return self.matrices[0].shape[0]

    @property
    def period(self) -> int:
        return len(self.matrices)

    @property
    def beta(self) -> float:
        return max(mixing_beta(W) for W in self.matrices)

    def __call__(self, t: int) -> WeightMatrix:
        return self.matrices[t % len(self.matrices)]

    def structure(self, t: int):
        if self.structures is None:
            return None
        return self.structures[t % len(self.structures)]

    def stacked(self, t0: int, rounds: int, dtype=np.float32) -> np.ndarray:
        """(rounds, n, n) array W^{t0}, ..., W^{t0+rounds-1} — the dense
        form of the schedule window."""
        return np.stack([self(t0 + r) for r in range(rounds)]).astype(dtype)

    def plan(self, t0: int = 0, rounds: int | None = None,
             validate: bool = True, pods: int | None = None,
             sparse: "bool | str" = "auto",
             personalized: bool = False) -> GossipPlan:
        """Lower rounds [t0, t0+rounds) (default: one full period) to a
        :class:`GossipPlan`; with ``validate`` each structured lowering is
        checked against its dense matrix via :func:`check_assumption3` and
        exact reconstruction.  ``pods`` enables the hierarchical two-level
        lowering for rounds that factor across pod boundaries, ``sparse``
        the edge-list fallback above the node/density threshold, and
        ``personalized`` stages every round's row-stochastic base weights
        as per-node rows for in-jit loss-proximity reweighting (see
        :func:`plan_round`)."""
        rounds = self.period if rounds is None else rounds
        plan = GossipPlan(tuple(
            plan_round(self(t0 + r), self.structure(t0 + r), pods=pods,
                       sparse=sparse, personalized=personalized)
            for r in range(rounds)))
        if validate:
            plan.validate()
        return plan


def schedule_from_topology(schedule, rule: str = "metropolis",
                           horizon: int | None = None) -> WeightSchedule:
    """Build a weight schedule from a topology schedule.

    Default rule is Metropolis-Hastings: unlike I - L/d_max it stays a
    strict average on degree-1 graphs (matchings), where the Laplacian rule
    degenerates to a pure swap with no contraction.

    Periodic schedules materialize one period; non-periodic ones (``period
    is None``, e.g. :func:`repro.core.topology.resampled_matching_schedule`)
    require ``horizon`` — the number of rounds the run will consume — and
    materialize exactly that window."""
    period = getattr(schedule, "period", 1)
    if period is None:
        if horizon is None:
            raise ValueError(
                "non-periodic topology schedule requires horizon=<rounds>")
        period = horizon
    mats, structs = [], []
    for t in range(period):
        adj = schedule(t)
        if rule == "laplacian_dmax":
            W = laplacian_rule(adj)
        elif rule == "metropolis":
            W = metropolis_weights(adj)
        else:
            raise ValueError(f"unknown rule {rule!r}")
        mats.append(W)
        structs.append(schedule.structure(t) if hasattr(schedule, "structure")
                       else topo.classify_adjacency(adj))
    return WeightSchedule(tuple(mats), tuple(structs))


def theorem3_weight_schedule(n: int, beta: float, avoid: Sequence[int] = ()) -> WeightSchedule:
    """The exact Theorem 3 matrices: W^t = I - (delta/n) L(S_{n,C^t}) with
    delta = n(1-beta)/ceil(n(1-beta)), giving ||W - 11^T/n||_2 = beta."""
    graphs = topo.sun_shaped_schedule(n, beta, avoid=avoid)
    k = int(math.ceil(n * (1.0 - beta)))
    if k >= n:
        W = beta * np.eye(n) + (1.0 - beta) * np.ones((n, n)) / n
        return WeightSchedule((W,), (topo.RoundStructure("complete"),))
    delta = n * (1.0 - beta) / k
    mats = tuple(
        laplacian_weights(graphs(t), delta / n) for t in range(graphs.period)
    )
    structs = tuple(graphs.structure(t) for t in range(graphs.period))
    return WeightSchedule(mats, structs)


# ---------------------------------------------------------------------------
# Multi-consensus (Algorithm 2) — host/matrix form
# ---------------------------------------------------------------------------

def multi_consensus(z: np.ndarray, schedule: MatrixSchedule, t1: int, t2: int) -> np.ndarray:
    """z^{(t2)} = W^{t2-1} ... W^{t1} z^{(t1)}  (Algorithm 2)."""
    out = z
    for t in range(t1, t2):
        out = schedule(t) @ out
    return out


def consensus_contraction(schedule: WeightSchedule, rounds: int) -> float:
    """||prod_{t<rounds} W^t - 11^T/n||_2 — should be <= beta^rounds (eq. 21)."""
    n = schedule.n
    P = np.eye(n)
    for t in range(rounds):
        P = schedule(t) @ P
    return float(np.linalg.norm(P - np.ones((n, n)) / n, ord=2))
