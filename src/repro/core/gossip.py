"""Gossip weight matrices and multi-consensus (paper §2 Assumption 3, Alg. 2).

Weight-matrix schedules are host-side numpy objects (tiny, n <= 64); the
values are fed into jitted distributed steps as regular array arguments so a
single compiled step serves the whole time-varying schedule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from . import topology as topo

WeightMatrix = np.ndarray  # (n, n) float64
MatrixSchedule = Callable[[int], WeightMatrix]


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def graph_laplacian(adj: topo.Adjacency) -> np.ndarray:
    a = adj.copy().astype(float)
    np.fill_diagonal(a, 0.0)
    deg = a.sum(axis=1)
    return np.diag(deg) - a


def laplacian_weights(adj: topo.Adjacency, delta_over_n: float) -> WeightMatrix:
    """W = I - (delta/n) * L(G) — the Theorem 3 rule (with delta_over_n =
    delta/n) and, with delta_over_n = 1/d_max, the classic Laplacian rule of
    Remark 5."""
    n = adj.shape[0]
    return np.eye(n) - delta_over_n * graph_laplacian(adj)


def laplacian_rule(adj: topo.Adjacency) -> WeightMatrix:
    """W = I - L / d_max (Remark 5)."""
    L = graph_laplacian(adj)
    dmax = float(np.max(np.diag(L)))
    if dmax == 0:
        return np.eye(adj.shape[0])
    return np.eye(adj.shape[0]) - L / dmax


def metropolis_weights(adj: topo.Adjacency) -> WeightMatrix:
    """Metropolis-Hastings doubly-stochastic weights for an undirected graph."""
    n = adj.shape[0]
    a = adj.copy()
    np.fill_diagonal(a, False)
    deg = a.sum(axis=1)
    W = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if a[i, j]:
                W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i, i] = 1.0 - W[i].sum()
    return W


def mixing_beta(W: WeightMatrix) -> float:
    """beta = ||W - (1/n) 11^T||_2 (Assumption 3.3)."""
    n = W.shape[0]
    return float(np.linalg.norm(W - np.ones((n, n)) / n, ord=2))


def check_assumption3(W: WeightMatrix, adj: topo.Adjacency | None = None,
                      beta: float | None = None, atol: float = 1e-9) -> None:
    """Raise AssertionError unless W satisfies Assumption 3 (sparsity pattern,
    double stochasticity, spectral bound)."""
    n = W.shape[0]
    ones = np.ones(n)
    if adj is not None:
        off = ~adj & ~np.eye(n, dtype=bool)
        assert np.allclose(W[off], 0.0, atol=atol), "W has weight on inactive links"
    assert np.allclose(W @ ones, ones, atol=atol), "W 1 != 1 (row sums)"
    assert np.allclose(ones @ W, ones, atol=atol), "1^T W != 1^T (col sums)"
    b = mixing_beta(W)
    if beta is not None:
        assert b <= beta + 1e-7, f"beta(W)={b} exceeds required {beta}"
    assert b <= 1.0 + 1e-9, f"beta(W)={b} > 1"


# ---------------------------------------------------------------------------
# Matrix schedules built from topology schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeightSchedule:
    """A periodic sequence of weight matrices W^t."""

    matrices: tuple  # tuple[np.ndarray]

    @property
    def n(self) -> int:
        return self.matrices[0].shape[0]

    @property
    def period(self) -> int:
        return len(self.matrices)

    @property
    def beta(self) -> float:
        return max(mixing_beta(W) for W in self.matrices)

    def __call__(self, t: int) -> WeightMatrix:
        return self.matrices[t % len(self.matrices)]

    def stacked(self, t0: int, rounds: int, dtype=np.float32) -> np.ndarray:
        """(rounds, n, n) array W^{t0}, ..., W^{t0+rounds-1} — the form the
        jitted distributed step consumes."""
        return np.stack([self(t0 + r) for r in range(rounds)]).astype(dtype)


def schedule_from_topology(schedule, rule: str = "metropolis") -> WeightSchedule:
    """Build a weight schedule from a (periodic) topology schedule.

    Default rule is Metropolis-Hastings: unlike I - L/d_max it stays a
    strict average on degree-1 graphs (matchings), where the Laplacian rule
    degenerates to a pure swap with no contraction."""
    period = getattr(schedule, "period", 1)
    mats = []
    for t in range(period):
        adj = schedule(t)
        if rule == "laplacian_dmax":
            W = laplacian_rule(adj)
        elif rule == "metropolis":
            W = metropolis_weights(adj)
        else:
            raise ValueError(f"unknown rule {rule!r}")
        mats.append(W)
    return WeightSchedule(tuple(mats))


def theorem3_weight_schedule(n: int, beta: float, avoid: Sequence[int] = ()) -> WeightSchedule:
    """The exact Theorem 3 matrices: W^t = I - (delta/n) L(S_{n,C^t}) with
    delta = n(1-beta)/ceil(n(1-beta)), giving ||W - 11^T/n||_2 = beta."""
    graphs = topo.sun_shaped_schedule(n, beta, avoid=avoid)
    k = int(math.ceil(n * (1.0 - beta)))
    if k >= n:
        W = beta * np.eye(n) + (1.0 - beta) * np.ones((n, n)) / n
        return WeightSchedule((W,))
    delta = n * (1.0 - beta) / k
    mats = tuple(
        laplacian_weights(graphs(t), delta / n) for t in range(graphs.period)
    )
    return WeightSchedule(mats)


# ---------------------------------------------------------------------------
# Multi-consensus (Algorithm 2) — host/matrix form
# ---------------------------------------------------------------------------

def multi_consensus(z: np.ndarray, schedule: MatrixSchedule, t1: int, t2: int) -> np.ndarray:
    """z^{(t2)} = W^{t2-1} ... W^{t1} z^{(t1)}  (Algorithm 2)."""
    out = z
    for t in range(t1, t2):
        out = schedule(t) @ out
    return out


def consensus_contraction(schedule: WeightSchedule, rounds: int) -> float:
    """||prod_{t<rounds} W^t - 11^T/n||_2 — should be <= beta^rounds (eq. 21)."""
    n = schedule.n
    P = np.eye(n)
    for t in range(rounds):
        P = schedule(t) @ P
    return float(np.linalg.norm(P - np.ones((n, n)) / n, ord=2))
