"""Single-source decentralized update-rule engine.

Every decentralized algorithm in this repo — the paper's DSGD / DSGT /
MC-DSGT (Algorithm 1), the D² baseline, and the federated family
(``local_sgd``, ``gt_local``) — is defined here exactly once as an
:class:`UpdateRule`: a declarative spec naming the rule's *structure*
(tracker/correction init, gradient-phase placement, pre/post-mix update
placement, rounds consumed per step, local-optimizer hook) that one generic
:func:`step` interprets.  Both runtimes consume the same rule:

* the host reference (:mod:`repro.core.algorithms`) binds :class:`EngineOps`
  to the stacked-``einsum`` multi-consensus and a ``grad_fn`` closure;
* the distributed runtime (:mod:`repro.dist.steps`) binds it to the
  mesh/plan mixers, the clipped R-microbatch loss/grad, and the bf16
  tracker cast.

Adding an algorithm means adding ONE rule spec (or one ``kind`` branch for
a genuinely new template) — zero edits in either runtime.

Rule structure cheat-sheet (γ = stepsize, u = local-optimizer transform,
Mix = the step's gossip window, R = accumulation/consensus rounds):

============  =========================================================
``dsgd``      x ← Mix(x − γ·u(g(x)))                       [12]
``local_sgd`` x ← Mix(x) − γ·u(g(Mix(x)))        (FedAvg over a
              federated schedule: empty rounds ⇒ pure local steps)
``dsgt``      x ← Mix(x − γ·h);  h ← Mix(h + g − g⁻)        [40]
``mc_dsgt``   same, R gossip rounds per mix + R-sample grads (Alg. 1)
``gt_local``  x ← Mix(x) − γ·h;  h ← Mix(h) + g − g⁻   (DIGing-style
              tracking with local updates: x and h share ONE round)
``d2``        x ← Mix(2x − x⁻ − γ(g − g⁻))                  [35]
``personalized``  x ← P(ℓ)·x − γ·u(g(x)) with P(ℓ) the loss-proximity
              similarity reweighting of the round's support (Dada-style
              confidence-weighted neighbor averaging; row-stochastic by
              construction, NOT doubly stochastic — outside Assumption 3)
============  =========================================================
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import compress

PyTree = Any
GradFn = Callable[[PyTree, jax.Array], PyTree]


# ---------------------------------------------------------------------------
# Shared pytree arithmetic (the only place update math lives)
# ---------------------------------------------------------------------------

def _axpy(a: float | jax.Array, x: PyTree, y: PyTree) -> PyTree:
    """y + a * x on every leaf (computed in y's dtype)."""
    return jax.tree.map(lambda u, v: v + a * u.astype(v.dtype), x, y)


def _accumulate(grad_fn: GradFn, x: PyTree, key: jax.Array, R: int) -> PyTree:
    """Gradient accumulation: (1/R) sum_r O(x; zeta_r) (eq. 19)."""
    if R == 1:
        return grad_fn(x, key)
    keys = jax.random.split(key, R)
    shapes = jax.eval_shape(grad_fn, x, keys[0])
    zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def body(acc, k):
        return jax.tree.map(jnp.add, acc, grad_fn(x, k)), None

    acc, _ = jax.lax.scan(body, zero, keys)
    return jax.tree.map(lambda a: a / R, acc)


def _tracker_delta(h: PyTree, g: PyTree, g_prev: PyTree) -> PyTree:
    """h + g − g_prev in the gradient dtype (trackers may be stored bf16)."""
    return jax.tree.map(
        lambda hh, gi, gp: hh.astype(gi.dtype) + gi - gp.astype(gi.dtype),
        h, g, g_prev)


# ---------------------------------------------------------------------------
# In-step observability scalars (repro.obs) — computed on device as part of
# the step's output pytree, so measuring a run adds no host syncs.
# ---------------------------------------------------------------------------

# The in-jit metric vocabulary.  Descriptions live in repro.obs.metrics;
# the computation lives HERE (once, for both runtimes).
OBS_METRICS = ("grad_norm", "consensus", "mix_residual", "tracker_residual")


def default_obs(rule: "UpdateRule") -> tuple:
    """The rule-appropriate metric set: every rule has a gradient, an
    iterate and a mix; only tracking rules carry a tracker."""
    if rule.kind == "tracking":
        return OBS_METRICS
    return tuple(m for m in OBS_METRICS if m != "tracker_residual")


def _fro(tree: PyTree) -> jax.Array:
    """Frobenius norm over every leaf, accumulated in f32."""
    tot = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree))
    return jnp.sqrt(tot)


def _obs_scalars(names, *, g: PyTree, x: PyTree, pre_mix: PyTree,
                 post_mix: PyTree, h: Optional[PyTree] = None) -> dict:
    """The requested in-step scalars, all f32 device scalars:

    ``grad_norm``         ||g||_F of this step's stacked oracle sample;
    ``consensus``         ||x − x̄||_F of the post-update iterate;
    ``mix_residual``      ||Mix(z) − z||_F of the step's gossip window —
                          how far mixing actually moved the state (0 on
                          empty/identity rounds);
    ``tracker_residual``  ||mean(h) − mean(g)||_F — drift of the gradient-
                          tracking invariant h̄ = ḡ (grows under clipping,
                          bf16 trackers, or non-doubly-stochastic repair);
                          0 for rules without a tracker.
    """
    out = {}
    for name in names:
        if name == "grad_norm":
            out[name] = _fro(g)
        elif name == "consensus":
            out[name] = _fro(jax.tree.map(
                lambda l: l - jnp.mean(l, axis=0, keepdims=True), x))
        elif name == "mix_residual":
            out[name] = _fro(jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                post_mix, pre_mix))
        elif name == "tracker_residual":
            out[name] = (jnp.zeros((), jnp.float32) if h is None else _fro(
                jax.tree.map(
                    lambda hh, gg: jnp.mean(hh.astype(jnp.float32), axis=0)
                    - jnp.mean(gg.astype(jnp.float32), axis=0), h, g)))
        else:
            raise ValueError(f"unknown obs metric {name!r} "
                             f"(have {OBS_METRICS})")
    return out


# ---------------------------------------------------------------------------
# Engine interfaces
# ---------------------------------------------------------------------------

class EngineState(NamedTuple):
    """Runtime-neutral algorithm state.  ``h`` doubles as the tracker
    (tracking rules) or x^{k-1} (difference rules); unused slots may be None
    (host) or zero trees (distributed runtime, for uniform sharding).
    ``res`` is the compressed-gossip error-feedback state: an
    ``(res_x, res_h)`` pair of per-node residual trees (``res_h`` None for
    rules without a tracker stream), or None when the rule carries no
    compression.  ``buf`` is the stale-window double buffer for overlapped
    gossip (``rule.delay > 0``): a ``(buf_x, buf_h)`` pair of FIFO queues —
    each a tuple of ``delay`` payload trees, oldest first — holding the
    pre-mix payloads of the last ``delay`` steps; None when ``delay=0`` so
    the synchronous state layout (and its checkpoints) is unchanged."""

    x: PyTree
    h: Optional[PyTree]
    g_prev: Optional[PyTree]
    opt: Any
    k: jax.Array
    res: Optional[Tuple] = None
    buf: Optional[Tuple] = None


class EngineOps(NamedTuple):
    """What a runtime must provide for the generic step to run.

    mix(offset, rounds, tree)
        Apply gossip rounds [t+offset, t+offset+rounds) of the step's
        window (host: a slice of the stacked weights; dist: the staged
        dense stack, the plan dispatcher, or the fused Pallas kernel).
    grad(x) -> (metrics, g)
        One accumulated stochastic-oracle sample per node (Assumption 2);
        ``metrics`` is runtime-defined (None on host, scalar loss in dist).
    local_update(g, opt_state) -> (update, opt_state)
        The local-optimizer hook (identity for the paper-pure rules).
    cast_aux(tree)
        Storage cast for tracker state (identity on host; bf16 in dist
        when ``aux_dtype`` is set).
    cmix(offset, rounds, tree, res, on) -> (tree, res)
        The compressed window mixer (required when the rule carries a
        :class:`repro.core.compress.CompressionConfig`): same rounds as
        ``mix`` but quantizing every payload with error-feedback residual
        ``res``; ``on`` gates warmup (see
        :func:`repro.core.compress.make_compressed_mixer`).
    pmix(offset, rounds, tree, losses) -> tree
        The personalized window mixer (required when
        ``rule.personalized``): same rounds as ``mix``, but each round's
        weights are reweighted in-jit by loss-proximity similarity
        (:func:`personalized_weights`) before mixing.  ``losses`` is the
        per-node (n,) loss vector of this step's oracle sample — for
        personalized rules ``grad`` must return it as its metrics.
    """

    mix: Callable[[int, int, PyTree], PyTree]
    grad: Callable[[PyTree], Tuple[Any, PyTree]]
    local_update: Callable[[PyTree, Any], Tuple[PyTree, Any]]
    cast_aux: Callable[[PyTree], PyTree]
    cmix: Optional[Callable] = None
    pmix: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class UpdateRule:
    """Declarative spec of one decentralized update rule.

    kind
        ``sgd`` (descend on the fresh gradient), ``tracking`` (descend on
        the gradient tracker h), or ``difference`` (D²'s x/g difference
        update).
    mix_before_update
        False: the gossip mix wraps the locally-updated iterate,
        x ← Mix(x − γu) (DSGD/DSGT families).  True: mix first, update
        locally after, x ← Mix(x) − γu — the federated placement, where an
        ``empty`` round degenerates to a pure local step.
    correction_in_mix
        tracking only.  True: h ← Mix(h + g − g⁻) (the paper's DSGT).
        False: h ← Mix(h) + g − g⁻ (DIGing/local-update placement — the
        correction stays local, so trackers keep tracking through empty
        rounds).
    shared_round
        tracking only.  True: x and h consume the SAME R-round window
        (weights_per_step = R); False: disjoint windows (2R).
    tracker_init
        ``mean``: h⁰ = node-mean of g⁰ replicated (Algorithm 1);
        ``local``: h⁰ = g⁰ per node (DIGing — no global reduction, in the
        local-update spirit).
    compression
        Optional :class:`repro.core.compress.CompressionConfig`: every
        gossip payload (the x stream and, for tracking rules, the h
        stream) is quantized per round with per-node error-feedback
        residuals carried in ``EngineState.res``.  None = full precision.
    delay
        Stale-window (overlapped) gossip.  ``delay=0`` is today's
        synchronous path, bit-exact (the delayed wrapper is never built).
        ``delay=d>0`` applies each step's gossip window to the payload
        from ``d`` steps ago and folds the *correction* into the fresh
        payload: ``out = payload + (Mix(stale) − stale)``.  Because the
        correction depends only on state that existed ``d`` steps earlier,
        the mix carries no data dependence on the current gradient and XLA
        is free to schedule the collectives concurrently with the grad
        computation (``obs_mix`` no longer serializes after ``obs_grad``).
        Doubly-stochastic windows keep the node mean invariant, so the
        tracking invariant h̄ = ḡ survives any delay.
    comm_interval
        Mix every ``k`` driver steps, pure local updates in between (the
        federated pattern, but as a runtime knob instead of a schedule
        property).  Skipped steps apply the identity mix — under
        ``delay>0`` they contribute a zero correction while the stale
        buffers keep advancing, so ``delay`` always counts steps, not
        mixes.  ``comm_interval=1`` is today's path, bit-exact.
    personalized / tau
        The Dada-style personalized variant (sgd kind only): each step the
        round's gossip support is reweighted by per-node loss proximity —
        α_ij = W_ij · exp(−tau·|ℓ_i − ℓ_j|), rows renormalized
        (:func:`personalized_weights`) — so nodes average mostly with
        neighbors whose data looks like theirs and the fleet converges to
        n *personalized* models instead of one consensus model.  The
        realized weights are row-stochastic by construction but data-
        dependent and NOT column-stochastic: this rule is deliberately
        OUTSIDE the paper's Assumption 3 (no doubly-stochastic consensus
        guarantee; the per-node objective is the local loss regularized by
        similar neighbors).  Incompatible with compression/delay/
        comm_interval — the personalized weights exist only in-jit.
    """

    name: str
    kind: str                          # 'sgd' | 'tracking' | 'difference'
    gamma: float
    R: int = 1
    mix_before_update: bool = False
    correction_in_mix: bool = True
    shared_round: bool = False
    tracker_init: str = "mean"
    supports_local_opt: bool = True
    compression: Optional[compress.CompressionConfig] = None
    delay: int = 0
    comm_interval: int = 1
    personalized: bool = False
    tau: float = 4.0

    def __post_init__(self):
        if self.kind not in ("sgd", "tracking", "difference"):
            raise ValueError(f"unknown rule kind {self.kind!r}")
        if self.personalized and self.kind != "sgd":
            raise ValueError("personalized reweighting is defined for the "
                             "sgd kind only")
        if self.personalized and (self.compression is not None or self.delay
                                  or self.comm_interval > 1):
            raise ValueError(
                "personalized weights are computed in-jit from this step's "
                "losses and cannot be combined with compression, delayed "
                "gossip, or comm_interval gating")
        if self.kind == "difference" and self.R != 1:
            raise ValueError("difference rules take one oracle sample/step")
        if self.delay < 0:
            raise ValueError(f"delay must be >= 0, got {self.delay}")
        if self.comm_interval < 1:
            raise ValueError(
                f"comm_interval must be >= 1, got {self.comm_interval}")
        if self.comm_interval > 1 and self.compression is not None:
            raise ValueError(
                "comm_interval > 1 cannot be combined with gossip "
                "compression (the error-feedback residual update cannot "
                "be gated per step); run one or the other")

    @property
    def weights_per_step(self) -> int:
        """Gossip rounds one step consumes (the paper's budget accounting)."""
        if self.kind == "difference":
            return 1
        if self.kind == "tracking" and not self.shared_round:
            return 2 * self.R
        return self.R

    @property
    def uses_tracker(self) -> bool:
        return self.kind == "tracking"

    @property
    def uses_prev_grad(self) -> bool:
        return self.kind in ("tracking", "difference")


# The one registry.  Adding an algorithm = adding a line here (or a factory
# below when it takes parameters beyond gamma/R).
def make_rule(name: str, gamma: float, R: int = 1,
              compression: Optional[compress.CompressionConfig] = None,
              delay: int = 0, comm_interval: int = 1,
              tau: float = 4.0) -> UpdateRule:
    specs = {
        "dsgd": dict(kind="sgd"),
        "local_sgd": dict(kind="sgd", mix_before_update=True),
        "dsgt": dict(kind="tracking", supports_local_opt=True),
        "mc_dsgt": dict(kind="tracking"),
        "gt_local": dict(kind="tracking", mix_before_update=True,
                         correction_in_mix=False, shared_round=True,
                         tracker_init="local"),
        "d2": dict(kind="difference", supports_local_opt=False),
        "personalized": dict(kind="sgd", personalized=True),
    }
    if name not in specs:
        raise ValueError(f"unknown algo {name!r} (have {sorted(specs)})")
    if name in ("dsgt", "d2") and R != 1:
        raise ValueError(f"{name} uses R=1 (MC-DSGT is the R-round variant)")
    return UpdateRule(name=name, gamma=gamma, R=(1 if name == "d2" else R),
                      compression=compression, delay=delay,
                      comm_interval=comm_interval, tau=tau, **specs[name])


ALGORITHMS = ("dsgd", "local_sgd", "dsgt", "mc_dsgt", "gt_local", "d2",
              "personalized")


def personalized_weights(Ws: jax.Array, losses: jax.Array,
                         tau: float) -> jax.Array:
    """Loss-proximity similarity reweighting of a gossip stack (the
    Dada-style confidence/similarity weights).

    ``Ws`` (R, n, n) is the round window's base weights — its support IS
    the communication graph; ``losses`` (n,) is this step's per-node loss.
    Each round's weights become α_ij = W_ij · exp(−tau·|ℓ_i − ℓ_j|) with
    rows renormalized, so the result is row-stochastic BY CONSTRUCTION but
    data-dependent and generally not column-stochastic — deliberately
    outside Assumption 3 (nodes with similar data pull toward each other;
    dissimilar neighbors are down-weighted instead of averaged away).
    """
    l = losses.astype(jnp.float32)
    sim = jnp.exp(-tau * jnp.abs(l[:, None] - l[None, :]))
    W = Ws.astype(jnp.float32) * sim[None]
    den = jnp.maximum(jnp.sum(W, axis=-1, keepdims=True), 1e-12)
    return W / den


# ---------------------------------------------------------------------------
# The generic step / warm start (interprets the spec — no per-name branches)
# ---------------------------------------------------------------------------

def _annotate(ops: EngineOps) -> EngineOps:
    """Wrap the runtime's grad/mix in :func:`jax.named_scope` so profiler
    traces (``repro.obs.trace`` ``--profile-dir``) decompose a fused step
    into its grad vs mix phases.  Pure metadata — no runtime cost."""
    def mix(off, r, tree):
        with jax.named_scope("obs_mix"):
            return ops.mix(off, r, tree)

    def grad(x):
        with jax.named_scope("obs_grad"):
            return ops.grad(x)

    pmix = ops.pmix
    if pmix is not None:
        base_pmix = pmix

        def pmix(off, r, tree, losses):
            with jax.named_scope("obs_mix"):
                return base_pmix(off, r, tree, losses)

    return ops._replace(mix=mix, grad=grad, pmix=pmix)


def step(rule: UpdateRule, state: EngineState, ops: EngineOps,
         obs: tuple = ()) -> Tuple[EngineState, Any]:
    """One round of ``rule``: returns (new state, runtime metrics).

    ``obs`` names in-step observability scalars (:data:`OBS_METRICS`) to
    compute on device alongside the update; when non-empty the second
    return value becomes ``(runtime_metrics, obs_dict)``.  Because the
    scalars ride the step's output pytree, enabling them adds device FLOPs
    only — no extra host round trips on the hot path."""
    gamma, R = rule.gamma, rule.R
    ops = _annotate(ops)

    # Compression: route every mix through the runtime's compressed window
    # mixer, threading the per-stream error-feedback residuals.  ``_res``
    # collects the updated residuals as the step body runs (the closures
    # mutate it at trace time — purely functional in the traced graph).
    comp = rule.compression
    if comp is None:
        mix_x = mix_h = ops.mix
        new_res = lambda: state.res
    else:
        if ops.cmix is None:
            raise ValueError(f"rule {rule.name!r} carries compression but "
                             "the runtime provided no EngineOps.cmix")
        if state.res is None:
            raise ValueError("compression needs residual state: init_state "
                             "materializes EngineState.res")
        _res = list(state.res)
        on = (state.k >= comp.warmup) if comp.warmup else None

        def _cmix(slot, off, r, tree):
            with jax.named_scope("obs_mix"):
                tree, _res[slot] = ops.cmix(off, r, tree, _res[slot], on)
            return tree

        mix_x = lambda off, r, tree: _cmix(0, off, r, tree)
        mix_h = lambda off, r, tree: _cmix(1, off, r, tree)
        new_res = lambda: tuple(_res)

    # comm_interval: gate the step's gossip window on the step counter —
    # skipped steps apply the identity mix (a pure local update, the
    # federated cadence as a runtime knob).  The gate sits INSIDE the delay
    # wrapper below, so skipped steps contribute a zero correction while
    # the stale buffers keep advancing: ``delay`` counts steps, not mixes.
    if rule.comm_interval > 1:
        mix_on = (state.k % rule.comm_interval) == 0

        def _gated(base):
            def gated(off, r, tree):
                return jax.lax.cond(mix_on, lambda tr: base(off, r, tr),
                                    lambda tr: tr, tree)
            return gated

        mix_x, mix_h = _gated(mix_x), _gated(mix_h)

    # Stale-window double buffer: mix the payload from ``delay`` steps ago
    # and fold only the *correction* into the fresh payload,
    # ``out = payload + (Mix(stale) − stale)``.  The mix then has no data
    # dependence on anything computed this step, so XLA may overlap the
    # gossip collectives with the gradient work (the double-buffered
    # runtime of the ROADMAP item).  Doubly-stochastic windows leave the
    # node mean of the correction at zero, so x̄ evolves exactly as in the
    # synchronous path and the tracking invariant h̄ = ḡ is preserved.
    # ``_buf`` mirrors the ``_res`` pattern: trace-time mutation of the
    # FIFO queues, purely functional in the traced graph.
    if rule.delay:
        if state.buf is None:
            raise ValueError("delay > 0 needs stale-payload buffers: "
                             "init_state materializes EngineState.buf")
        _buf = [None if q is None else list(q) for q in state.buf]
        _store = ((lambda t: t), ops.cast_aux)   # per-stream storage cast

        def _delayed(slot, base):
            def delayed(off, r, tree):
                q = _buf[slot]
                stale = q[0]
                mixed = base(off, r, stale)
                out = jax.tree.map(
                    lambda t, m, s: (t.astype(jnp.float32)
                                     + m.astype(jnp.float32)
                                     - s.astype(jnp.float32)).astype(t.dtype),
                    tree, mixed, stale)
                _buf[slot] = q[1:] + [_store[slot](tree)]
                return out
            return delayed

        mix_x, mix_h = _delayed(0, mix_x), _delayed(1, mix_h)
        new_buf = lambda: tuple(None if q is None else tuple(q) for q in _buf)
    else:
        new_buf = lambda: state.buf

    def out(metrics, *, g, x, pre_mix, post_mix, h=None):
        if not obs:
            return metrics
        return metrics, _obs_scalars(obs, g=g, x=x, pre_mix=pre_mix,
                                     post_mix=post_mix, h=h)

    if rule.kind == "sgd":
        if rule.personalized:
            # Personalized neighbor averaging: the oracle runs first so the
            # per-node losses (the grad metrics, by EngineOps contract) can
            # reweight this round's support in-jit.  The mix is
            # row-stochastic only — see :func:`personalized_weights`.
            if ops.pmix is None:
                raise ValueError(f"rule {rule.name!r} is personalized but "
                                 "the runtime provided no EngineOps.pmix")
            metrics, g = ops.grad(state.x)
            upd, opt = ops.local_update(g, state.opt)
            z = _axpy(-gamma, upd, state.x)
            x = ops.pmix(0, rule.weights_per_step, z, metrics)
            aux = out(metrics, g=g, x=x, pre_mix=z, post_mix=x)
            return state._replace(x=x, opt=opt, k=state.k + 1,
                                  res=new_res(), buf=new_buf()), aux
        if rule.mix_before_update:
            xm = mix_x(0, rule.weights_per_step, state.x)
            metrics, g = ops.grad(xm)
            upd, opt = ops.local_update(g, state.opt)
            x = _axpy(-gamma, upd, xm)
            aux = out(metrics, g=g, x=x, pre_mix=state.x, post_mix=xm)
        else:
            metrics, g = ops.grad(state.x)
            upd, opt = ops.local_update(g, state.opt)
            z = _axpy(-gamma, upd, state.x)
            x = mix_x(0, rule.weights_per_step, z)
            aux = out(metrics, g=g, x=x, pre_mix=z, post_mix=x)
        return state._replace(x=x, opt=opt, k=state.k + 1,
                              res=new_res(), buf=new_buf()), aux

    if rule.kind == "difference":
        if state.g_prev is None:
            raise ValueError("call warm_start first")
        metrics, g = ops.grad(state.x)
        z = jax.tree.map(
            lambda xk, xm, gk, gp: 2.0 * xk - xm.astype(xk.dtype)
            - gamma * (gk - gp.astype(gk.dtype)),
            state.x, state.h, g, state.g_prev)
        x = mix_x(0, 1, z)
        aux = out(metrics, g=g, x=x, pre_mix=z, post_mix=x)
        # x^{k-1} rides in the h slot, uncast to keep the difference exact
        return EngineState(x=x, h=state.x, g_prev=ops.cast_aux(g),
                           opt=state.opt, k=state.k + 1,
                           res=new_res(), buf=new_buf()), aux

    # tracking
    if state.h is None:
        raise ValueError("call warm_start first (h requires g at x0)")
    d, opt = ops.local_update(state.h, state.opt)
    if rule.mix_before_update:
        xm = mix_x(0, R, state.x)
        x = _axpy(-gamma, d, xm)
        pre, post = state.x, xm
    else:
        z = _axpy(-gamma, d, state.x)
        x = mix_x(0, R, z)
        pre, post = z, x
    metrics, g = ops.grad(x)
    h_off = 0 if rule.shared_round else R
    if rule.correction_in_mix:
        h = mix_h(h_off, R, _tracker_delta(state.h, g, state.g_prev))
    else:
        h = _tracker_delta(mix_h(h_off, R, state.h), g, state.g_prev)
    aux = out(metrics, g=g, x=x, pre_mix=pre, post_mix=post, h=h)
    return EngineState(x=x, h=ops.cast_aux(h), g_prev=ops.cast_aux(g),
                       opt=opt, k=state.k + 1, res=new_res(),
                       buf=new_buf()), aux


def warm_start(rule: UpdateRule, state: EngineState,
               ops: EngineOps) -> EngineState:
    """Tracker/correction initialization, defined once per rule kind:

    * sgd rules need none;
    * difference rules set x⁻ = x⁰ (in the h slot) and g⁻ = 0, so the
      first update reduces to one DSGD step;
    * tracking rules query the oracle at x⁰ and set h⁰ per
      ``rule.tracker_init``.
    """
    if rule.kind == "sgd":
        return state
    if rule.kind == "difference":
        zeros = jax.tree.map(jnp.zeros_like, state.x)
        return state._replace(h=state.x, g_prev=ops.cast_aux(zeros))
    _, g0 = ops.grad(state.x)
    if rule.tracker_init == "mean":
        h0 = jax.tree.map(
            lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True),
                                       g.shape), g0)
    else:
        h0 = g0
    state = state._replace(h=ops.cast_aux(h0), g_prev=ops.cast_aux(g0))
    if rule.delay and state.buf is not None:
        # Seed the tracker-stream stale queue with the warm-start payload
        # (h⁰ is the natural t<0 tracker payload: h₋₁ + g₀ − g₋₁ = h⁰).
        state = state._replace(
            buf=(state.buf[0], tuple(state.h for _ in range(rule.delay))))
    return state


def init_state(rule: UpdateRule, x0: PyTree, *, opt_init=None,
               aux_init=None, res_dtype=None) -> EngineState:
    """Fresh state: ``aux_init`` materializes the h/g_prev slots (None →
    host-style lazy slots; the dist runtime passes a zeros/bf16 factory so
    every state leaf exists for sharding).  When the rule carries
    compression, the error-feedback residuals are materialized as zeros
    (``res_dtype`` overrides the leaf dtype — pass the runtime's
    ``aux_dtype`` so stored residuals match ``cast_aux``'s storage)."""
    opt = opt_init(x0) if opt_init is not None else None
    mk = (lambda: aux_init(x0)) if aux_init is not None else (lambda: None)
    res = (compress.init_residual(x0, rule.uses_tracker, dtype=res_dtype)
           if rule.compression is not None else None)
    buf = None
    if rule.delay:
        # Stale-payload FIFO queues (oldest first).  The x stream seeds with
        # x⁰ — with broadcast-identical init, Mix(x⁰) − x⁰ = 0, so the first
        # ``delay`` steps see a zero correction: exactly the overlapped-
        # communication semantics where round t's results land at t+delay.
        # The tracker stream starts as zeros/None and is re-seeded with the
        # warm-start payload by :func:`warm_start`.
        hq = (tuple(mk() for _ in range(rule.delay))
              if rule.uses_tracker else None)
        buf = (tuple(x0 for _ in range(rule.delay)), hq)
    return EngineState(x=x0, h=mk(), g_prev=mk(), opt=opt,
                       k=jnp.zeros((), jnp.int32), res=res, buf=buf)
