"""Zero-chain hard instances for the lower bound (paper Appendix B).

Implements the Carmon et al. component functions (Lemma 7), their odd/even
splits (Lemma 8), the progress measure ``prog``, and the two adversarial
instances of Theorem 4:

* Instance 1 — homogeneous f_i with the coordinate-masking Bernoulli oracle
  (drives the statistical term sqrt(Delta L sigma^2 / nT)).
* Instance 2 — odd/even split functions assigned to two far-apart node sets
  I1, I2 on the sun-shaped schedule (drives the network term
  Delta L / (T (1 - beta))).

These are *analysis* objects used by tests/benchmarks to validate the bound
empirically; they are not on the production training path.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtr

# Lemma 7 constants
DELTA0 = 12.0    # h(0) - inf h <= DELTA0 * d
ELL0 = 152.0     # smoothness of h
G_INF = 23.0     # sup ||grad h||_inf


def psi(z: jax.Array) -> jax.Array:
    """psi(z) = exp(1 - 1/(2z-1)^2) for z > 1/2, else 0 (safe for autodiff)."""
    z = jnp.asarray(z, jnp.float64) if jax.config.jax_enable_x64 else jnp.asarray(z, jnp.float32)
    safe = jnp.where(z > 0.5, z, 0.75)  # keep denominator away from 0
    val = jnp.exp(1.0 - 1.0 / (2.0 * safe - 1.0) ** 2)
    return jnp.where(z > 0.5, val, 0.0)


def phi(z: jax.Array) -> jax.Array:
    """phi(z) = sqrt(e) * int_{-inf}^z exp(-t^2/2) dt = sqrt(2 pi e) * ndtr(z)."""
    return math.sqrt(2.0 * math.pi * math.e) * ndtr(z)


def _chain_terms(x: jax.Array) -> jax.Array:
    """terms[j] = psi(-x_j) phi(-x_{j+1}) - psi(x_j) phi(x_{j+1}), j = 0..d-2."""
    a, b = x[:-1], x[1:]
    return psi(-a) * phi(-b) - psi(a) * phi(b)


def h(x: jax.Array) -> jax.Array:
    """Lemma 7 zero-chain function."""
    return -psi(1.0) * phi(x[0]) + jnp.sum(_chain_terms(x))


def h1(x: jax.Array) -> jax.Array:
    """Lemma 8: even-j links (j = 2, 4, ... in 1-based indexing) + head term."""
    terms = _chain_terms(x)                      # index j-1 for 1-based j
    d = x.shape[0]
    j = jnp.arange(1, d)                         # 1-based link index
    even = (j % 2 == 0).astype(terms.dtype)
    return -2.0 * psi(1.0) * phi(x[0]) + 2.0 * jnp.sum(terms * even)


def h2(x: jax.Array) -> jax.Array:
    """Lemma 8: odd-j links."""
    terms = _chain_terms(x)
    d = x.shape[0]
    j = jnp.arange(1, d)
    odd = (j % 2 == 1).astype(terms.dtype)
    return 2.0 * jnp.sum(terms * odd)


def prog(x: jax.Array) -> jax.Array:
    """prog(x) = max{j : x_j != 0} (1-based), 0 if x = 0."""
    d = x.shape[-1]
    idx = jnp.arange(1, d + 1)
    return jnp.max(jnp.where(x != 0, idx, 0), axis=-1)


# ---------------------------------------------------------------------------
# Instance 1: homogeneous functions + Bernoulli coordinate-masking oracle
# ---------------------------------------------------------------------------

class Instance1(NamedTuple):
    d: int
    lam: float
    L: float
    p: float

    def f(self, x: jax.Array) -> jax.Array:
        return (self.L * self.lam ** 2 / ELL0) * h(x / self.lam)

    def grad_f(self, x: jax.Array) -> jax.Array:
        return jax.grad(self.f)(x)

    def oracle(self, x: jax.Array, key: jax.Array) -> jax.Array:
        """[O(x; Z)]_j = [grad f(x)]_j (1 + 1{j > prog(x)} (Z/p - 1))."""
        g = self.grad_f(x)
        z = jax.random.bernoulli(key, self.p).astype(g.dtype)
        j = jnp.arange(1, self.d + 1)
        mask = (j > prog(x)).astype(g.dtype)
        return g * (1.0 + mask * (z / self.p - 1.0))


def make_instance1(L: float, Delta: float, sigma: float, n: int, T: int) -> Instance1:
    """Parameter choices from Appendix B.1, Instance 1 (Step 3)."""
    lam = (ELL0 / L) * (Delta * L * sigma ** 2 / (3 * n * T * ELL0 * DELTA0 * G_INF ** 2)) ** 0.25
    d = max(2, int((3 * L * Delta * n * T * G_INF ** 2 / (sigma ** 2 * ELL0 * DELTA0)) ** 0.5))
    p = min(L ** 2 * lam ** 2 * G_INF ** 2 / (ELL0 ** 2 * sigma ** 2), 1.0)
    return Instance1(d=d, lam=lam, L=L, p=p)


# ---------------------------------------------------------------------------
# Instance 2: odd/even split functions on far-apart node sets
# ---------------------------------------------------------------------------

class Instance2(NamedTuple):
    n: int
    d: int
    lam: float
    L: float

    @property
    def set1(self) -> tuple:
        return tuple(range(0, math.ceil(self.n / 4)))           # I1 (0-based)

    @property
    def set2(self) -> tuple:
        return tuple(range(self.n - math.ceil(self.n / 4), self.n))  # I2

    def _scale(self) -> float:
        return self.n / math.ceil(self.n / 4)

    def f_i(self, i: int, x: jax.Array) -> jax.Array:
        c = self.L * self.lam ** 2 / (2 * ELL0)
        s = self._scale()
        if i in self.set1:
            return c * (s / 2.0) * h1(x / self.lam)
        if i in self.set2:
            return c * (s / 2.0) * h2(x / self.lam)
        return jnp.zeros((), x.dtype)

    def f(self, x: jax.Array) -> jax.Array:
        """Global average = L lam^2 h(x/lam) / (2 ell0) * (scale*|I|/n) = ..."""
        vals = [self.f_i(i, x) for i in range(self.n)]
        return sum(vals) / self.n

    def grad_stacked(self, xs: jax.Array) -> jax.Array:
        """Full-batch per-node gradients for stacked models xs: (n, d)."""
        def g_one(i, x):
            return jax.grad(lambda y: self.f_i(i, y))(x)
        return jnp.stack([g_one(i, xs[i]) for i in range(self.n)])


def make_instance2(L: float, Delta: float, n: int, beta: float, T: int,
                   C: float = 1.0) -> Instance2:
    """Parameter choices from Appendix B.1, Instance 2 (Step 3)."""
    d = max(2, int(C * (1 - beta) * T) + 2)
    lam = (2 * ELL0 / L) * math.sqrt(
        2 * Delta * L / (3 * C * (1 - beta) * T * 2 * ELL0 * DELTA0)) / 2
    # ensure the Delta budget (14): d * lam^2 <= 2 ell0 Delta / (L DELTA0)
    cap = math.sqrt(2 * ELL0 * Delta / (L * DELTA0 * d))
    lam = min(lam, cap)
    return Instance2(n=n, d=d, lam=lam, L=L)
