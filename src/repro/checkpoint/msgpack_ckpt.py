"""msgpack-based pytree checkpointing (no external deps beyond msgpack)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _dtype_from_name(name: str) -> np.dtype:
    """Resolve a saved dtype.  ``dtype.str`` round-trips for the native
    numpy types but NOT for the ml_dtypes extension types (bfloat16 & co.
    stringify as raw-void '<V2'), so we save ``dtype.name`` and resolve
    extension names through ml_dtypes.  Checkpoints written before the
    name-based format stored the mangled '<V2' itself; bfloat16 is the only
    2-byte extension dtype the trainer ever stored, so map it back."""
    import ml_dtypes

    try:
        dt = np.dtype(name)
        if dt.kind != "V":
            return dt
        if dt.itemsize == 2:  # legacy checkpoint's mangled bf16
            return np.dtype(ml_dtypes.bfloat16)
        raise ValueError(f"unresolvable void dtype {name!r} in checkpoint")
    except TypeError:
        pass
    try:
        return np.dtype(getattr(ml_dtypes, name))
    except AttributeError:
        raise ValueError(f"unknown checkpoint dtype {name!r}") from None


def _pack_leaf(x):
    arr = np.asarray(x)
    return {b"dtype": arr.dtype.name.encode(), b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _unpack_leaf(d):
    dtype = _dtype_from_name(d[b"dtype"].decode())
    arr = np.frombuffer(d[b"data"], dtype=dtype)
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {b"step": step,
               b"treedef": str(treedef).encode(),
               b"leaves": [_pack_leaf(x) for x in leaves]}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (treedef source of truth)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree.flatten(like)
    restored = [_unpack_leaf(d) for d in payload[b"leaves"]]
    assert len(restored) == len(leaves), "checkpoint/tree leaf count mismatch"
    for a, b in zip(restored, leaves):
        assert a.shape == b.shape, (a.shape, b.shape)
    return jax.tree.unflatten(treedef, restored), payload[b"step"]
