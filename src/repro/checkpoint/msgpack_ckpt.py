"""msgpack-based pytree checkpointing (no external deps beyond msgpack)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    arr = np.asarray(x)
    return {b"dtype": arr.dtype.str.encode(), b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _unpack_leaf(d):
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {b"step": step,
               b"treedef": str(treedef).encode(),
               b"leaves": [_pack_leaf(x) for x in leaves]}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (treedef source of truth)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree.flatten(like)
    restored = [_unpack_leaf(d) for d in payload[b"leaves"]]
    assert len(restored) == len(leaves), "checkpoint/tree leaf count mismatch"
    for a, b in zip(restored, leaves):
        assert a.shape == b.shape, (a.shape, b.shape)
    return jax.tree.unflatten(treedef, restored), payload[b"step"]
