"""Mesh-axis helpers and collective utilities shared by the distributed
sharding rules and the jitted steps.

Axis-name contract (see ``launch/mesh.py``):

* the decentralized **node** axis is ``"node"`` when present (hierarchical
  mesh), else ``("pod", "data")`` on the multi-pod mesh, else ``"data"``;
* **tensor-parallel** width is the combined ``("fsdp", "model")`` group —
  every sharded weight dimension is split over the whole group so the
  hierarchical mesh gets fsdp x model ways per node copy.

Also hosts the fused Pallas multi-consensus: the whole stacked state is
flattened to one ``(n, D)`` matrix and pushed through the
``kernels.gossip_matmul.gossip_mix`` kernel, which chains all R gossip
rounds in VMEM with exactly one HBM read/write of the state.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Mesh-axis helpers (operate on .axis_names / .shape only, so unit tests can
# pass a mocked mesh object)
# ---------------------------------------------------------------------------

def node_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the decentralized node dimension."""
    names = tuple(mesh.axis_names)
    if "node" in names:
        return ("node",)
    if "pod" in names and "data" in names:
        return ("pod", "data")
    if "data" in names:
        return ("data",)
    return ()


def tp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the tensor-parallel (weight-sharding) dimension."""
    names = tuple(mesh.axis_names)
    return tuple(a for a in ("fsdp", "model") if a in names)


def axis_size(mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def n_nodes(mesh) -> int:
    return axis_size(mesh, node_axes(mesh))


def spec_entry(axes: Sequence[str]):
    """PartitionSpec entry for an axis group: name, tuple of names, or None."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def fit(dim: int, axes: Sequence[str], mesh):
    """``spec_entry(axes)`` when the axis group evenly divides ``dim``, else
    None (jax requires divisible shard sizes)."""
    if axes and dim % axis_size(mesh, axes) == 0:
        return spec_entry(axes)
    return None


# ---------------------------------------------------------------------------
# Pytree numerics helpers
# ---------------------------------------------------------------------------

def tree_cast(tree: PyTree, dtype: Optional[jnp.dtype]) -> PyTree:
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def stage_plan(plan) -> dict:
    """Upload a :class:`repro.core.gossip.GossipPlan`'s tensors to device
    ONCE.  The returned dict is passed unchanged to every jitted step, which
    indexes it by ``t % period`` — the whole schedule crosses the host
    boundary a single time for the lifetime of the run.  Delegates to the
    canonical :func:`repro.core.driver.stage_plan` (one staging path for
    the CLI, the benchmarks, and the tests)."""
    from ..core import driver

    return driver.stage_plan(plan)


# ---------------------------------------------------------------------------
# Stacked-pytree <-> (n, D) matrix
# ---------------------------------------------------------------------------

def flatten_stacked(tree: PyTree):
    """Flatten a node-stacked pytree (every leaf (n, ...)) into one f32
    ``(n, D_total)`` matrix plus the metadata to invert the transform."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    mat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    meta = (treedef, [(leaf.shape, leaf.dtype) for leaf in leaves])
    return mat, meta


def unflatten_stacked(mat: jax.Array, meta) -> PyTree:
    treedef, infos = meta
    out, off = [], 0
    for shape, dtype in infos:
        size = math.prod(shape[1:]) if len(shape) > 1 else 1
        out.append(mat[:, off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def fused_multi_consensus(Ws: jax.Array, tree: PyTree, *, block_d: int = 1024,
                          interpret: bool = True) -> PyTree:
    """Algorithm 2 through the Pallas ``gossip_mix`` kernel: one fused pass
    applying all R matrices with a single HBM round-trip of the state.

    ``interpret=True`` is the CPU fallback (Python interpretation of the
    kernel body); set False on real TPU hardware.
    """
    from ..kernels import ops

    mat, meta = flatten_stacked(tree)
    n, D = mat.shape
    bd = min(block_d, D)
    pad = (-D) % bd
    if pad:  # zero columns mix to zero under any W, sliced away below
        mat = jnp.pad(mat, ((0, 0), (0, pad)))
    out = ops.gossip_mix(Ws.astype(jnp.float32), mat, use_pallas=True,
                         interpret=interpret, block_d=bd)
    return unflatten_stacked(out[:, :D], meta)
