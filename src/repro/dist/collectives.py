"""Mesh-axis helpers and collective utilities shared by the distributed
sharding rules and the jitted steps.

Axis-name contract (see ``launch/mesh.py``):

* the decentralized **node** axis is ``"node"`` when present (hierarchical
  mesh), else ``("pod", "data")`` on the multi-pod mesh, else ``"data"``;
* **tensor-parallel** width is the combined ``("fsdp", "model")`` group —
  every sharded weight dimension is split over the whole group so the
  hierarchical mesh gets fsdp x model ways per node copy.

Also hosts the fused Pallas multi-consensus: the whole stacked state is
flattened to one ``(n, D)`` matrix and pushed through the
``kernels.gossip_matmul.gossip_mix`` kernel, which chains all R gossip
rounds in VMEM with exactly one HBM read/write of the state.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Mesh-axis helpers (operate on .axis_names / .shape only, so unit tests can
# pass a mocked mesh object)
# ---------------------------------------------------------------------------

def node_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the decentralized node dimension."""
    names = tuple(mesh.axis_names)
    if "node" in names:
        return ("node",)
    if "pod" in names and "data" in names:
        return ("pod", "data")
    if "data" in names:
        return ("data",)
    return ()


def tp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the tensor-parallel (weight-sharding) dimension."""
    names = tuple(mesh.axis_names)
    return tuple(a for a in ("fsdp", "model") if a in names)


def axis_size(mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def n_nodes(mesh) -> int:
    return axis_size(mesh, node_axes(mesh))


def spec_entry(axes: Sequence[str]):
    """PartitionSpec entry for an axis group: name, tuple of names, or None."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def fit(dim: int, axes: Sequence[str], mesh):
    """``spec_entry(axes)`` when the axis group evenly divides ``dim``, else
    None (jax requires divisible shard sizes)."""
    if axes and dim % axis_size(mesh, axes) == 0:
        return spec_entry(axes)
    return None


# ---------------------------------------------------------------------------
# Pytree numerics helpers
# ---------------------------------------------------------------------------

def tree_cast(tree: PyTree, dtype: Optional[jnp.dtype]) -> PyTree:
    if dtype is None:
        return tree
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def stage_plan(plan) -> dict:
    """Upload a :class:`repro.core.gossip.GossipPlan`'s tensors to device
    ONCE.  The returned dict is passed unchanged to every jitted step, which
    indexes it by ``t % period`` — the whole schedule crosses the host
    boundary a single time for the lifetime of the run.  Delegates to the
    canonical :func:`repro.core.driver.stage_plan` (one staging path for
    the CLI, the benchmarks, and the tests)."""
    from ..core import driver

    return driver.stage_plan(plan)


# ---------------------------------------------------------------------------
# Stacked-pytree <-> (n, D) matrix
# ---------------------------------------------------------------------------

def flatten_stacked(tree: PyTree):
    """Flatten a node-stacked pytree (every leaf (n, ...)) into one f32
    ``(n, D_total)`` matrix plus the metadata to invert the transform."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    mat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    meta = (treedef, [(leaf.shape, leaf.dtype) for leaf in leaves])
    return mat, meta


def unflatten_stacked(mat: jax.Array, meta) -> PyTree:
    treedef, infos = meta
    out, off = [], 0
    for shape, dtype in infos:
        size = math.prod(shape[1:]) if len(shape) > 1 else 1
        out.append(mat[:, off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def fused_multi_consensus(Ws: jax.Array, tree: PyTree, *, block_d: int = 1024,
                          interpret="auto") -> PyTree:
    """Algorithm 2 through the Pallas ``gossip_mix`` kernel: one fused pass
    applying all R matrices with a single HBM round-trip of the state.

    ``interpret`` follows the one kernel policy
    (:func:`repro.kernels.ops.resolve_interpret`): ``"auto"`` compiles on
    TPU backends and falls back to interpreter mode elsewhere; pass a bool
    to force either mode.
    """
    from ..kernels import ops

    mat, meta = flatten_stacked(tree)
    n, D = mat.shape
    bd = min(block_d, D)
    pad = (-D) % bd
    if pad:  # zero columns mix to zero under any W, sliced away below
        mat = jnp.pad(mat, ((0, 0), (0, pad)))
    out = ops.gossip_mix(Ws.astype(jnp.float32), mat, use_pallas=True,
                         interpret=interpret, block_d=bd)
    return unflatten_stacked(out[:, :D], meta)


def fused_quantized_consensus(Ws: jax.Array, tree: PyTree, res: PyTree, *,
                              cfg, on=None, block_d: int = 1024,
                              interpret="auto"):
    """Error-feedback compressed multi-consensus through the fused Pallas
    ``quantized_gossip_mix`` kernel: quantize -> mix -> dequantize ->
    residual update for all R rounds in one VMEM-resident pass.

    ``cfg`` is a :class:`repro.core.compress.CompressionConfig`; ``res``
    the per-node residual pytree (same structure as ``tree``); ``on`` the
    warmup gate (None = always compressed, else a traced bool selecting
    the plain full-precision ``gossip_mix`` during warmup).  Returns
    ``(mixed tree, new residual tree)``.  The group-aligned flattening
    (:func:`repro.core.compress.flatten_grouped`) guarantees the kernel's
    block/group boundaries match the unfused reference exactly.
    """
    from ..core import compress
    from ..kernels import ops

    mat, meta = compress.flatten_grouped(tree, cfg.group)
    rmat, rmeta = compress.flatten_grouped(res, cfg.group)
    n, D = mat.shape
    bd = min(block_d, D)
    bd = max(cfg.group, (bd // cfg.group) * cfg.group)
    pad = (-D) % bd
    if pad:  # whole zero groups: a fixed point of quantize/mix/residual
        mat = jnp.pad(mat, ((0, 0), (0, pad)))
        rmat = jnp.pad(rmat, ((0, 0), (0, pad)))
    Ws = Ws.astype(jnp.float32)

    def compressed(mat, rmat):
        return ops.quantized_gossip_mix(
            Ws, mat, rmat, scheme=cfg.scheme, group=cfg.group,
            error_feedback=cfg.error_feedback, use_pallas=True,
            interpret=interpret, block_d=bd)

    def plain(mat, rmat):
        return ops.gossip_mix(Ws, mat, use_pallas=True, interpret=interpret,
                              block_d=bd), rmat

    if on is None:
        out, rout = compressed(mat, rmat)
    else:
        out, rout = jax.lax.cond(on, compressed, plain, mat, rmat)
    return (compress.unflatten_grouped(out[:, :D], meta),
            compress.unflatten_grouped(rout[:, :D], rmeta))
