"""PartitionSpec rules for every pytree the runtime moves over a mesh.

``param_specs`` maps model/state/cache pytrees to PartitionSpecs by leaf
*name* (the leaf names in ``models/*.py`` are load-bearing):

* weight matrices shard one dimension over the combined tensor-parallel
  group ``("fsdp", "model")`` — attention q/kv heads (falling back to
  head_dim per ``cfg.attn_shard_fallback``), MoE experts, embedding vocab,
  and the last (else first) dimension of generic matrices;
* cache leaves (``k``/``v``/``conv``/``h``/...) shard their batch dimension
  over the node/data axes and KV heads over the tensor-parallel group;
* ``stacked_nodes=True`` prepends the node axes to every leaf (the leading
  node dimension of MC-DSGT's stacked state), ``audio_cache=True`` prepends
  a replicated layer-stack axis (the encoder-decoder cache is vmapped over
  layers instead of scan-stacked under a ``units`` key);
* any dimension the mesh does not evenly divide is replicated instead —
  the rules degrade, never error, as meshes shrink.

Only ``mesh.axis_names`` and ``mesh.shape`` are consulted, so the fast unit
tests drive these functions with a mocked mesh object.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from .collectives import fit, n_nodes, node_axes, spec_entry, tp_axes  # noqa: F401

PyTree = Any

# leaf names that belong to serve caches / recurrent state, not weights
_CACHE_POS = ("kpos", "cross_kpos")
_CACHE_KV = ("k", "v", "cross_k", "cross_v")
_CACHE_STATE = ("conv", "h")
# param collections stacked over a leading layer axis (scan/vmap)
_STACKED_COLLECTIONS = ("units", "enc", "dec")
# path keys marking attention parameter groups
_ATTN_GROUPS = ("attn", "self", "cross")


def _path_names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def _attn_spec(name, dims, cfg, mesh, tp):
    """wq (D, H, hd) / wk, wv (D, KV, hd) / wo (H, hd, D) / b{q,k,v} (H, hd)."""
    if name in ("wq", "wk", "wv") and len(dims) == 3:
        heads = fit(dims[1], tp, mesh)
        if heads is not None:
            return [None, heads, None]
        if getattr(cfg, "attn_shard_fallback", "head_dim") == "head_dim":
            return [None, None, fit(dims[2], tp, mesh)]
        return [None, None, None]
    if name == "wo" and len(dims) == 3:
        return [fit(dims[0], tp, mesh), None, None]
    if name in ("bq", "bk", "bv") and len(dims) == 2:
        return [fit(dims[0], tp, mesh), None]
    return None


def _moe_spec(name, dims, mesh, tp):
    """router (D, E) / wi, wg (E, D, F) / wo (E, F, D) — expert-parallel when
    E divides the group, else shard the expert FFN dimension."""
    if name == "router" and len(dims) == 2:
        return [None, fit(dims[1], tp, mesh)]
    if name in ("wi", "wg") and len(dims) == 3:
        experts = fit(dims[0], tp, mesh)
        if experts is not None:
            return [experts, None, None]
        return [None, None, fit(dims[2], tp, mesh)]
    if name == "wo" and len(dims) == 3:
        experts = fit(dims[0], tp, mesh)
        if experts is not None:
            return [experts, None, None]
        return [None, fit(dims[1], tp, mesh), None]
    return None


def _cache_spec(name, dims, mesh, nd, tp):
    if name in _CACHE_POS:
        return [None] * len(dims)
    if name in _CACHE_KV and len(dims) == 4:  # (B, C, KV, hd)
        return [fit(dims[0], nd, mesh), None, fit(dims[2], tp, mesh), None]
    # conv / recurrent state: (B, ...) — batch-shard only
    return [fit(dims[0], nd, mesh)] + [None] * (len(dims) - 1)


def _generic_spec(dims, mesh, tp):
    if len(dims) < 2:
        return [None] * len(dims)
    last = fit(dims[-1], tp, mesh)
    if last is not None:
        return [None] * (len(dims) - 1) + [last]
    first = fit(dims[0], tp, mesh)
    return [first] + [None] * (len(dims) - 1)


def _leaf_spec(path, leaf, cfg, mesh, *, stacked_nodes, audio_cache):
    names = _path_names(path)
    name = names[-1] if names else ""
    dims = list(leaf.shape)
    prefix = []
    if stacked_nodes and dims:
        prefix.append(fit(dims[0], node_axes(mesh), mesh))
        dims = dims[1:]
    if dims and (audio_cache or any(k in names for k in _STACKED_COLLECTIONS)):
        prefix.append(None)  # scan/vmap layer-stack axis stays replicated
        dims = dims[1:]

    nd, tp = node_axes(mesh), tp_axes(mesh)
    body = None
    if name in _CACHE_POS + _CACHE_KV + _CACHE_STATE:
        body = _cache_spec(name, dims, mesh, nd, tp)
    elif any(g in names for g in _ATTN_GROUPS):
        body = _attn_spec(name, dims, cfg, mesh, tp)
    elif "moe" in names:
        body = _moe_spec(name, dims, mesh, tp)
    if body is None and name == "embedding" and len(dims) == 2:
        body = [fit(dims[0], tp, mesh), None]
    if body is None and name == "unembed" and len(dims) == 2:
        body = [None, fit(dims[1], tp, mesh)]
    if body is None:
        body = _generic_spec(dims, mesh, tp)
    return P(*(prefix + body))


def param_specs(tree: PyTree, cfg, mesh, *, stacked_nodes: bool = False,
                audio_cache: bool = False) -> PyTree:
    """PartitionSpecs for a params / tracker-state / serve-cache pytree.

    ``stacked_nodes``: leaves carry a leading node dimension (MC-DSGT state);
    ``audio_cache``: leaves carry a leading per-layer stack dimension (the
    encoder-decoder cache layout).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg, mesh,
                                      stacked_nodes=stacked_nodes,
                                      audio_cache=audio_cache),
        tree)


def batch_specs(batch: PyTree, mesh, *, stacked_nodes: bool = False) -> PyTree:
    """Specs for an input batch: the leading dimension (node axis when
    ``stacked_nodes``, else the global batch) shards over the node/data axes;
    everything downstream of it is replicated."""
    del stacked_nodes  # same leading-axis rule either way
    nd = node_axes(mesh)

    def one(leaf):
        dims = leaf.shape
        if not dims:
            return P()
        return P(*([fit(dims[0], nd, mesh)] + [None] * (len(dims) - 1)))

    return jax.tree.map(one, batch)
