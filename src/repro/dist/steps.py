"""Jitted distributed steps: MC-DSGT / DSGT / DSGD over a stacked node state.

``make_train_step`` builds the three callables the drivers and tests consume:

* ``init_state(key, n, dtype)`` — n identical model copies (leading node
  axis on every leaf) plus zeroed tracker state;
* ``warm_start(state, batch)`` — Algorithm 1's initialization: tracker
  h^0 = (1/n) sum_i g~_i^0 replicated from R accumulated oracle queries;
* ``step(state, batch, weights) -> (state, {"loss": ...})`` — one paper
  round.  ``batch`` leaves are (n, R, b, ...) so the R gradient-accumulation
  microbatches are Assumption 2's independent oracle draws; ``weights`` is
  the (2R, n, n) gossip stack (or (2R, n) center masks for the structured
  sun path).

The gossip mixing runs through :func:`repro.core.algorithms.multi_consensus`
(an einsum over the node axis — under GSPMD with the node axis sharded this
lowers to cross-node collectives), through the structured sun rewrite,
through the fused Pallas kernel (``gossip_impl="pallas"``) which applies all
R rounds in one VMEM-resident pass, or — ``gossip_impl="auto"`` — through a
:class:`repro.core.gossip.GossipPlan` that dispatches every round to its
cheapest lowering (sun / one-peer matching / complete-graph mean / dense)
from plan tensors staged on device once.

Tracker state (h, g_prev) can be held in a lower precision via ``aux_dtype``
(H2: bf16 trackers halve the steady-state HBM of the tracker copies);
updates are computed in the gradient dtype and cast on store.

Unlike the host-side reference in :mod:`repro.core.algorithms` (which stays
letter-faithful to Algorithm 1), the runtime clips each node's accumulated
oracle sample to a global norm (``clip``, default 1.0) before it enters the
tracker — the standard LM-training stabilizer.  Raw per-sequence gradient
norms on the transformer configs sit at 5-12, so the paper-pure update at
the test stepsizes is past the edge of stability; the tracker then simply
tracks the mean *clipped* gradient.  ``clip=None`` restores the pure update.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import algorithms as alg
from . import collectives as coll

PyTree = Any


class TrainState(NamedTuple):
    x: PyTree                  # stacked model copies (n leading)
    h: PyTree                  # gradient tracker (zeros until warm_start)
    g_prev: PyTree             # previous accumulated oracle sample
    step: jax.Array            # round counter
    opt: Any = None            # local-optimizer state (framework extension)


def make_train_step(model, cfg, *, algo: str = "mc_dsgt", gamma: float,
                    R: int = 1, aux_dtype=None, gossip_impl: str = "dense",
                    sun_delta: Optional[float] = None, local_opt=None,
                    clip: Optional[float] = 1.0, unroll: bool = False,
                    pallas_block_d: int = 1024, pallas_interpret: bool = True,
                    plan=None, mesh=None, gossip_axis: str = "data",
                    auto_dense: str = "einsum"):
    """Build (init_state, warm_start, step) for one decentralized algorithm.

    gossip_impl: 'dense' (einsum multi-consensus), 'sun' (structured
    sun-graph rewrite; ``weights`` becomes (2R, n) center masks and
    ``sun_delta`` must be given), 'pallas' (fused gossip_mix kernel;
    ``pallas_interpret=True`` is the CPU fallback), or 'auto' (per-round
    structured dispatch from a :class:`repro.core.gossip.GossipPlan`;
    ``plan`` must be given).

    For 'dense'/'sun'/'pallas' the step is ``step(state, batch, weights)``
    with ``weights`` the per-step gossip stack.  For 'auto' it is
    ``step(state, batch, plan_tensors, t)``: ``plan_tensors`` is
    ``plan.tensors()`` staged on device ONCE, ``t`` the start round modulo
    the plan period — a Python int when ``step.gossip_dispatch == 'static'``
    (jit it with ``static_argnums=3``), a traced scalar otherwise.
    ``mesh``/``gossip_axis`` enable the explicit ppermute matching lowering;
    ``auto_dense='pallas'`` routes runs of dense rounds through the fused
    Pallas kernel instead of the einsum scan.
    """
    if algo not in ("mc_dsgt", "dsgt", "dsgd", "d2"):
        raise ValueError(f"unknown algo {algo!r}")
    if gossip_impl not in ("dense", "sun", "pallas", "auto"):
        raise ValueError(f"unknown gossip_impl {gossip_impl!r}")
    if gossip_impl == "sun" and sun_delta is None:
        raise ValueError("gossip_impl='sun' requires sun_delta")
    if gossip_impl == "auto" and plan is None:
        raise ValueError("gossip_impl='auto' requires plan=GossipPlan")
    if algo == "d2" and local_opt is not None:
        raise ValueError("algo='d2' does not support local_opt (the x^{k-1} "
                         "difference update has no local-optimizer hook)")

    def _mc(Ws, tree):
        if gossip_impl == "sun":
            return alg.sun_multi_consensus(Ws, sun_delta, tree, unroll=True)
        if gossip_impl == "pallas":
            return coll.fused_multi_consensus(
                Ws, tree, block_d=pallas_block_d, interpret=pallas_interpret)
        return alg.multi_consensus(Ws, tree, unroll=unroll)

    if gossip_impl == "auto":
        dense_block = None
        if auto_dense == "pallas":
            dense_block = lambda Ws, tr: coll.fused_multi_consensus(
                Ws, tr, block_d=pallas_block_d, interpret=pallas_interpret)
        _plan_mix = alg.make_plan_mixer(plan, mesh=mesh, axis=gossip_axis,
                                        dense_block=dense_block)

    def _mix_rounds(gossip, t, offset, rounds, tree):
        """Rounds [t+offset, t+offset+rounds) — from the staged plan under
        'auto', else the per-step ``weights`` stack slice."""
        if gossip_impl == "auto":
            return _plan_mix(gossip, t + offset, rounds, tree)
        return _mc(gossip[offset:offset + rounds], tree)

    def _clip(g):
        if clip is None:
            return g
        nrm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                           for l in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, clip / (nrm + 1e-12))
        return jax.tree.map(lambda l: l * scale.astype(l.dtype), g)

    def _grads(x_stacked, batch):
        """Per-node R-sample gradient accumulation (clipped); returns
        (mean loss, stacked grads)."""
        def per_node(params, node_batch):  # node_batch leaves: (R, b, ...)
            vg = jax.value_and_grad(model.train_loss)
            if R == 1:
                loss, g = vg(params, jax.tree.map(lambda t: t[0], node_batch))
                return loss, _clip(g)
            if unroll:
                loss = jnp.zeros((), jnp.float32)
                g = jax.tree.map(jnp.zeros_like, params)
                for r in range(R):
                    micro = jax.tree.map(lambda t: t[r], node_batch)
                    l, gr = vg(params, micro)
                    loss = loss + l
                    g = jax.tree.map(jnp.add, g, gr)
            else:
                def body(carry, micro):
                    l, gr = vg(params, micro)
                    return (carry[0] + l,
                            jax.tree.map(jnp.add, carry[1], gr)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(jnp.zeros_like, params))
                (loss, g), _ = jax.lax.scan(body, zero, node_batch)
            return loss / R, _clip(jax.tree.map(lambda t: t / R, g))

        losses, grads = jax.vmap(per_node)(x_stacked, batch)
        return jnp.mean(losses), grads

    def init_state(key, n: int, dtype) -> TrainState:
        params = model.init(key, dtype)
        x = alg.broadcast_nodes(params, n)
        aux = jax.tree.map(
            lambda l: jnp.zeros(l.shape, aux_dtype or l.dtype), x)
        opt = local_opt.init(x) if local_opt is not None else None
        return TrainState(x=x, h=aux, g_prev=aux, step=jnp.zeros((), jnp.int32),
                          opt=opt)

    def warm_start(state: TrainState, batch) -> TrainState:
        if algo == "dsgd":
            return state
        if algo == "d2":
            # first step reduces to DSGD: x^{-1} = x^0 (held in the h slot),
            # g^{-1} = 0 — matching repro.core.algorithms.warm_start
            zeros = jax.tree.map(jnp.zeros_like, state.x)
            return state._replace(h=state.x,
                                  g_prev=coll.tree_cast(zeros, aux_dtype))
        _, g0 = _grads(state.x, batch)
        h0 = jax.tree.map(
            lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True),
                                       g.shape), g0)
        return state._replace(h=coll.tree_cast(h0, aux_dtype),
                              g_prev=coll.tree_cast(g0, aux_dtype))

    def dsgd_core(state: TrainState, batch, gossip, t):
        loss, g = _grads(state.x, batch)
        if local_opt is not None:
            upd, opt = local_opt.update(g, state.opt)
        else:
            upd, opt = g, state.opt
        x = _mix_rounds(gossip, t, 0, R, alg._axpy(-gamma, upd, state.x))
        return state._replace(x=x, step=state.step + 1, opt=opt), {"loss": loss}

    def tracker_core(state: TrainState, batch, gossip, t):
        if local_opt is not None:
            d, opt = local_opt.update(state.h, state.opt)
        else:
            d, opt = state.h, state.opt
        x = _mix_rounds(gossip, t, 0, R, alg._axpy(-gamma, d, state.x))
        loss, g = _grads(x, batch)
        delta = jax.tree.map(
            lambda h, gi, gp: h.astype(gi.dtype) + gi - gp.astype(gi.dtype),
            state.h, g, state.g_prev)
        h = coll.tree_cast(_mix_rounds(gossip, t, R, R, delta), aux_dtype)
        return TrainState(x=x, h=h, g_prev=coll.tree_cast(g, aux_dtype),
                          step=state.step + 1, opt=opt), {"loss": loss}

    def d2_core(state: TrainState, batch, gossip, t):
        # D^2 [35]: x^{k+1} = W(2 x^k - x^{k-1} - gamma (g^k - g^{k-1}));
        # x^{k-1} rides in the tracker (h) slot, uncast to keep the
        # difference update exact.  Consumes ONE gossip round per step.
        loss, g = _grads(state.x, batch)
        z = jax.tree.map(
            lambda xk, xm, gk, gp: 2.0 * xk - xm.astype(xk.dtype)
            - gamma * (gk - gp.astype(gk.dtype)),
            state.x, state.h, g, state.g_prev)
        x = _mix_rounds(gossip, t, 0, 1, z)
        return TrainState(x=x, h=state.x, g_prev=coll.tree_cast(g, aux_dtype),
                          step=state.step + 1, opt=state.opt), {"loss": loss}

    core = {"dsgd": dsgd_core, "d2": d2_core}.get(algo, tracker_core)
    if gossip_impl == "auto":
        step = core
        step.gossip_dispatch = _plan_mix.dispatch
    else:
        def step(state: TrainState, batch, weights):
            return core(state, batch, weights, 0)
    return init_state, jax.jit(warm_start), step


def make_prefill_step(model, cfg):
    """(params, batch, cache) -> (last-position logits, filled cache)."""
    del cfg

    def step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return step


def make_serve_step(model, cfg):
    """(params, token, cache, pos) -> (logits, cache) for one decode step."""
    del cfg

    def step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return step
