"""Jitted distributed steps over a stacked node state.

This module is a thin ADAPTER: the update arithmetic for every algorithm
(mc_dsgt / dsgt / dsgd / d2 / local_sgd / gt_local) lives once in
:mod:`repro.core.engine`; here we only bind the engine's :class:`EngineOps`
to the distributed substrate — the mesh/plan gossip mixers, the clipped
R-microbatch loss/grad, and the bf16 tracker cast.

``make_train_step`` builds the three callables the drivers and tests consume:

* ``init_state(key, n, dtype)`` — n identical model copies (leading node
  axis on every leaf) plus zeroed tracker state;
* ``warm_start(state, batch)`` — the rule's tracker init (Algorithm 1's
  h^0 = (1/n) sum_i g~_i^0 replicated for the MC-DSGT family);
* ``step(state, batch, weights) -> (state, {"loss": ...})`` — one paper
  round.  ``batch`` leaves are (n, R, b, ...) so the R gradient-accumulation
  microbatches are Assumption 2's independent oracle draws; ``weights`` is
  the (2R, n, n) gossip stack (or (2R, n) center masks for the structured
  sun path).

The gossip mixing runs through :func:`repro.core.algorithms.multi_consensus`
(an einsum over the node axis — under GSPMD with the node axis sharded this
lowers to cross-node collectives), through the structured sun rewrite,
through the fused Pallas kernel (``gossip_impl="pallas"``) which applies all
R rounds in one VMEM-resident pass, or — ``gossip_impl="auto"`` — through a
:class:`repro.core.gossip.GossipPlan` that dispatches every round to its
cheapest lowering (sun / one-peer matching / complete-graph mean / dense)
from plan tensors staged on device once.

Tracker state (h, g_prev) can be held in a lower precision via ``aux_dtype``
(H2: bf16 trackers halve the steady-state HBM of the tracker copies);
updates are computed in the gradient dtype and cast on store.

Unlike the host-side reference in :mod:`repro.core.algorithms` (which stays
letter-faithful to Algorithm 1), the runtime clips each node's accumulated
oracle sample to a global norm (``clip``, default 1.0) before it enters the
tracker — the standard LM-training stabilizer.  Raw per-sequence gradient
norms on the transformer configs sit at 5-12, so the paper-pure update at
the test stepsizes is past the edge of stability; the tracker then simply
tracks the mean *clipped* gradient.  ``clip=None`` restores the pure update.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import algorithms as alg, compress, engine
from . import collectives as coll

PyTree = Any


class TrainState(NamedTuple):
    x: PyTree                  # stacked model copies (n leading)
    h: PyTree                  # gradient tracker (zeros until warm_start)
    g_prev: PyTree             # previous accumulated oracle sample
    step: jax.Array            # round counter
    opt: Any = None            # local-optimizer state (framework extension)
    res: Any = None            # compressed-gossip EF residuals (x, h)
    buf: Any = None            # stale-payload queues (x, h) when delay>0


def make_train_step(model, cfg, *, algo: str = "mc_dsgt", gamma: float,
                    R: int = 1, aux_dtype=None, gossip_impl: str = "dense",
                    sun_delta: Optional[float] = None, local_opt=None,
                    clip: Optional[float] = 1.0, unroll: bool = False,
                    pallas_block_d: int = 1024, pallas_interpret="auto",
                    plan=None, mesh=None, gossip_axis: str = "data",
                    auto_dense: str = "einsum", obs: tuple = (),
                    compression: Optional[compress.CompressionConfig] = None,
                    delay: int = 0, comm_interval: int = 1,
                    tau: float = 4.0):
    """Build (init_state, warm_start, step) for one decentralized algorithm.

    gossip_impl: 'dense' (einsum multi-consensus), 'sun' (structured
    sun-graph rewrite; ``weights`` becomes (2R, n) center masks and
    ``sun_delta`` must be given), 'pallas' (fused gossip_mix kernel;
    ``pallas_interpret`` follows :func:`repro.kernels.ops.resolve_interpret`
    — "auto" interprets off-TPU), or 'auto' (per-round structured dispatch
    from a :class:`repro.core.gossip.GossipPlan`; ``plan`` must be given).

    ``compression`` (a :class:`repro.core.compress.CompressionConfig`)
    turns every gossip payload into its quantized error-feedback form; the
    'pallas' impl routes it through the fused quantize->mix->dequantize
    kernel, every other impl wraps its per-round mixer via
    :func:`repro.core.compress.make_compressed_mixer` — bit-identical
    semantics either way.

    For 'dense'/'sun'/'pallas' the step is ``step(state, batch, weights)``
    with ``weights`` the per-step gossip stack.  For 'auto' it is
    ``step(state, batch, plan_tensors, t)``: ``plan_tensors`` is
    ``plan.tensors()`` staged on device ONCE, ``t`` the start round modulo
    the plan period — a Python int when ``step.gossip_dispatch == 'static'``
    (jit it with ``static_argnums=3``), a traced scalar otherwise.
    ``mesh``/``gossip_axis`` enable the explicit ppermute matching lowering;
    ``auto_dense='pallas'`` routes runs of dense rounds through the fused
    Pallas kernel instead of the einsum scan.

    ``obs`` names in-jit observability scalars (repro.obs /
    :data:`repro.core.engine.OBS_METRICS`): when non-empty the step's
    output dict gains an ``"obs"`` entry of device scalars, computed by
    the shared engine — no extra host syncs.

    ``delay`` > 0 enables the stale-window double buffer (overlapped
    gossip): the step mixes the payload from ``delay`` steps ago and folds
    the correction into the fresh payload, so the collectives carry no
    data dependence on the current grad and XLA may run them concurrently
    (see :class:`repro.core.engine.UpdateRule`).  ``comm_interval`` mixes
    every k steps (identity mix in between).  Both default to today's
    synchronous path, bit-exact.
    """
    rule = engine.make_rule(algo, gamma=gamma,
                            R=(1 if algo == "d2" else R),
                            compression=compression, delay=delay,
                            comm_interval=comm_interval, tau=tau)
    if gossip_impl not in ("dense", "sun", "pallas", "auto"):
        raise ValueError(f"unknown gossip_impl {gossip_impl!r}")
    if rule.personalized and gossip_impl not in ("dense", "auto"):
        raise ValueError("personalized weights are reweighted per step in "
                         "full precision; use gossip_impl 'dense' or 'auto'")
    if gossip_impl == "sun" and sun_delta is None:
        raise ValueError("gossip_impl='sun' requires sun_delta")
    if gossip_impl == "auto" and plan is None:
        raise ValueError("gossip_impl='auto' requires plan=GossipPlan")
    if local_opt is not None and not rule.supports_local_opt:
        raise ValueError(f"algo={algo!r} does not support a local-optimizer "
                         "hook")

    def _mc(Ws, tree):
        if gossip_impl == "sun":
            return alg.sun_multi_consensus(Ws, sun_delta, tree, unroll=True)
        if gossip_impl == "pallas":
            return coll.fused_multi_consensus(
                Ws, tree, block_d=pallas_block_d, interpret=pallas_interpret)
        return alg.multi_consensus(Ws, tree, unroll=unroll)

    if gossip_impl == "auto":
        dense_block = None
        if auto_dense == "pallas":
            dense_block = lambda Ws, tr: coll.fused_multi_consensus(
                Ws, tr, block_d=pallas_block_d, interpret=pallas_interpret)
        _plan_mix = alg.make_plan_mixer(plan, mesh=mesh, axis=gossip_axis,
                                        dense_block=dense_block)

    def _mix_rounds(gossip, t, offset, rounds, tree):
        """Rounds [t+offset, t+offset+rounds) — from the staged plan under
        'auto', else the per-step ``weights`` stack slice."""
        if gossip_impl == "auto":
            return _plan_mix(gossip, t + offset, rounds, tree)
        return _mc(gossip[offset:offset + rounds], tree)

    def _clip(g):
        if clip is None:
            return g
        nrm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                           for l in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, clip / (nrm + 1e-12))
        return jax.tree.map(lambda l: l * scale.astype(l.dtype), g)

    def _grads(x_stacked, batch):
        """Per-node R-sample gradient accumulation (clipped); returns
        (mean loss, stacked grads) — or (per-node losses, stacked grads)
        for personalized rules, whose pmix needs the (n,) loss vector as
        its similarity signal (the ``core`` wrapper re-means it for the
        step's "loss" output)."""
        def per_node(params, node_batch):  # node_batch leaves: (R, b, ...)
            vg = jax.value_and_grad(model.train_loss)
            if R == 1:
                loss, g = vg(params, jax.tree.map(lambda t: t[0], node_batch))
                return loss, _clip(g)
            if unroll:
                loss = jnp.zeros((), jnp.float32)
                g = jax.tree.map(jnp.zeros_like, params)
                for r in range(R):
                    micro = jax.tree.map(lambda t: t[r], node_batch)
                    l, gr = vg(params, micro)
                    loss = loss + l
                    g = jax.tree.map(jnp.add, g, gr)
            else:
                def body(carry, micro):
                    l, gr = vg(params, micro)
                    return (carry[0] + l,
                            jax.tree.map(jnp.add, carry[1], gr)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(jnp.zeros_like, params))
                (loss, g), _ = jax.lax.scan(body, zero, node_batch)
            return loss / R, _clip(jax.tree.map(lambda t: t / R, g))

        losses, grads = jax.vmap(per_node)(x_stacked, batch)
        if rule.personalized:
            return losses, grads
        return jnp.mean(losses), grads

    def init_state(key, n: int, dtype) -> TrainState:
        params = model.init(key, dtype)
        x = alg.broadcast_nodes(params, n)
        aux = jax.tree.map(
            lambda l: jnp.zeros(l.shape, aux_dtype or l.dtype), x)
        opt = local_opt.init(x) if local_opt is not None else None
        res = (compress.init_residual(x, rule.uses_tracker, dtype=aux_dtype)
               if compression is not None else None)
        buf = None
        if rule.delay:
            # Stale-payload FIFO queues, mirroring engine.init_state: the x
            # stream seeds with x⁰ (zero correction for the first ``delay``
            # steps under broadcast-identical init); the tracker stream is
            # re-seeded with h⁰ by warm_start.
            hq = (tuple(aux for _ in range(rule.delay))
                  if rule.uses_tracker else None)
            buf = (tuple(x for _ in range(rule.delay)), hq)
        return TrainState(x=x, h=aux, g_prev=aux, step=jnp.zeros((), jnp.int32),
                          opt=opt, res=res, buf=buf)

    # Bind the engine's abstract ops to this runtime: the selected gossip
    # mixer, the clipped R-microbatch oracle, the local-optimizer hook and
    # the bf16 tracker cast.  The update arithmetic itself is
    # engine.step(rule, ...) — shared verbatim with the host reference.
    def _ops(batch, gossip, t):
        cmix = None
        if compression is not None:
            if gossip_impl == "pallas":
                # Fully fused: quantize -> mix -> dequantize -> residual in
                # one VMEM-resident Pallas pass over the whole window.
                cmix = lambda off, r, tree, res, on: \
                    coll.fused_quantized_consensus(
                        gossip[off:off + r], tree, res, cfg=compression,
                        on=on, block_d=pallas_block_d,
                        interpret=pallas_interpret)
            else:
                cmix = compress.make_compressed_mixer(
                    lambda idx, m: _mix_rounds(gossip, t, idx, 1, m),
                    compression)
        pmix = None
        if rule.personalized:
            # In-jit loss-proximity reweighting of the round window's base
            # weights: the staged per-node rows ("pW" — never a dense
            # fallback) under 'auto', the per-step stack slice under
            # 'dense'.  ``losses`` is _grads' per-node vector.
            if gossip_impl == "auto":
                def pmix(off, r, tree, losses):
                    idxs = (t + off + jnp.arange(r)) % plan.period
                    Ws = engine.personalized_weights(
                        jnp.take(gossip["pW"], idxs, axis=0), losses, rule.tau)
                    return alg.multi_consensus(Ws, tree, unroll=unroll)
            else:
                def pmix(off, r, tree, losses):
                    Ws = engine.personalized_weights(
                        gossip[off:off + r], losses, rule.tau)
                    return alg.multi_consensus(Ws, tree, unroll=unroll)
        return engine.EngineOps(
            mix=lambda off, r, tree: _mix_rounds(gossip, t, off, r, tree),
            grad=lambda x: _grads(x, batch),  # metrics = scalar mean loss
            local_update=(local_opt.update if local_opt is not None
                          else (lambda g, s: (g, s))),
            cast_aux=lambda tree: coll.tree_cast(tree, aux_dtype),
            cmix=cmix,
            pmix=pmix)

    def _to_engine(s: TrainState) -> engine.EngineState:
        return engine.EngineState(s.x, s.h, s.g_prev, s.opt, s.step,
                                  res=s.res, buf=s.buf)

    def _to_train(s: engine.EngineState) -> TrainState:
        return TrainState(x=s.x, h=s.h, g_prev=s.g_prev, step=s.k, opt=s.opt,
                          res=s.res, buf=s.buf)

    def warm_start(state: TrainState, batch) -> TrainState:
        ops = _ops(batch, None, 0)  # warm start never gossips
        return _to_train(engine.warm_start(rule, _to_engine(state), ops))

    # personalized _grads returns the per-node loss vector (pmix's
    # similarity signal); the step's "loss" output stays the scalar mean
    _loss_out = jnp.mean if rule.personalized else (lambda m: m)

    def core(state: TrainState, batch, gossip, t):
        es, aux = engine.step(rule, _to_engine(state),
                              _ops(batch, gossip, t), obs=obs)
        if obs:
            loss, scalars = aux
            return _to_train(es), {"loss": _loss_out(loss), "obs": scalars}
        return _to_train(es), {"loss": _loss_out(aux)}
    if gossip_impl == "auto":
        step = core
        step.gossip_dispatch = _plan_mix.dispatch
    else:
        def step(state: TrainState, batch, weights):
            return core(state, batch, weights, 0)
    return init_state, jax.jit(warm_start), step


def make_prefill_step(model, cfg):
    """(params, batch, cache) -> (last-position logits, filled cache)."""
    del cfg

    def step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return step


def make_serve_step(model, cfg):
    """(params, token, cache, pos) -> (logits, cache) for one decode step."""
    del cfg

    def step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return step
