"""Distributed runtime: mesh-axis sharding rules, collective utilities and
jitted decentralized train/serve steps over a jax mesh.

* :mod:`repro.dist.sharding` — PartitionSpecs for params, stacked node
  states, caches and batches on any of the repo's meshes.
* :mod:`repro.dist.collectives` — mesh-axis helpers, stacked-pytree
  flattening, and the fused Pallas multi-consensus path.
* :mod:`repro.dist.steps` — ``make_train_step`` (MC-DSGT / DSGT / DSGD),
  ``make_prefill_step`` and ``make_serve_step``.
"""

from . import collectives, sharding, steps  # noqa: F401
from .sharding import batch_specs, n_nodes, param_specs  # noqa: F401
from .steps import TrainState, make_prefill_step, make_serve_step, make_train_step  # noqa: F401
