"""Data pipeline.

Two producers:

* ``TokenStream`` — deterministic synthetic LM token batches, shaped for the
  decentralized trainer: (n_nodes, R, batch, seq) so each node's R gradient
  accumulation rounds see distinct microbatches (Assumption 2's independent
  oracle queries).  Per-node PRNG folding keeps node i's stream independent
  of n or the host count.

* ``logreg_dataset`` — the paper's §6 protocol: binary classification data
  partitioned *heterogeneously* (a half of the nodes hold 80% positive
  samples, the other half 80% negative).

Both producers support **Dirichlet(alpha) heterogeneity** — the standard
federated-learning non-iid protocol (Hsu et al.): each node's class/token
distribution is an independent draw from a Dirichlet prior, so small alpha
concentrates each node on a few classes while alpha → ∞ recovers iid.
``TokenStream(hetero_alpha=...)`` skews per-node token marginals;
``dirichlet_partition`` splits a labelled pool into per-node index sets.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    n_nodes: int
    rounds: int            # R microbatches per step
    batch: int             # per-node, per-round sequences
    seq: int
    seed: int = 0
    active_vocab: int = 0          # 0 = full vocab; else restrict to first k
                                   # tokens (learnable low-entropy stream)
    hetero_alpha: Optional[float] = None   # Dirichlet(alpha) per-node token
                                           # marginals; None = iid uniform
    _node_logits: Optional[jnp.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)  # cached Dirichlet draw
    arch_type: str = "dense"
    d_model: int = 0
    frontend_tokens: int = 0
    encoder_seq: int = 0

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def node_token_logits(self) -> jnp.ndarray:
        """(n_nodes, active_vocab) log-probabilities: node i's token marginal
        is an independent Dirichlet(alpha) draw (deterministic in seed —
        nodes keep their distribution for the whole run, so the draw and its
        device upload happen once and are cached)."""
        if self.hetero_alpha is None:
            raise ValueError("node_token_logits requires hetero_alpha")
        if self._node_logits is None:
            hi = self.active_vocab or self.vocab_size
            rng = np.random.default_rng(
                np.random.SeedSequence((self.seed, 0xD11C)))
            probs = rng.dirichlet([self.hetero_alpha] * hi,
                                  size=self.n_nodes)
            self._node_logits = jnp.asarray(
                np.log(np.maximum(probs, 1e-20)), jnp.float32)
        return self._node_logits

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        shape = (self.n_nodes, self.rounds, self.batch, self.seq)
        hi = self.active_vocab or self.vocab_size
        if self.hetero_alpha is not None:
            logits = self.node_token_logits()
            keys = jax.random.split(key, self.n_nodes)
            tokens = jax.vmap(
                lambda k, lg: jax.random.categorical(
                    k, lg, shape=shape[1:]))(keys, logits).astype(jnp.int32)
        else:
            tokens = jax.random.randint(key, shape, 0, hi, jnp.int32)
        out = {"tokens": tokens}
        if self.arch_type == "vlm":
            kp = jax.random.fold_in(key, 1)
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                kp, shape[:3] + (self.frontend_tokens, self.d_model))
            out["tokens"] = tokens[..., :self.seq - self.frontend_tokens]
        elif self.arch_type == "audio":
            kp = jax.random.fold_in(key, 2)
            out["frames"] = 0.02 * jax.random.normal(
                kp, shape[:3] + (self.encoder_seq, self.d_model))
        return out


def token_stream_for(cfg, n_nodes: int, rounds: int, batch: int, seq: int,
                     seed: int = 0, active_vocab: int = 0,
                     hetero_alpha: Optional[float] = None) -> TokenStream:
    return TokenStream(vocab_size=cfg.vocab_size, n_nodes=n_nodes,
                       rounds=rounds, batch=batch, seq=seq, seed=seed,
                       active_vocab=active_vocab, hetero_alpha=hetero_alpha,
                       arch_type=cfg.arch_type, d_model=cfg.d_model,
                       frontend_tokens=cfg.frontend_tokens,
                       encoder_seq=cfg.encoder_seq)


# ---------------------------------------------------------------------------
# Dirichlet node partitions (federated non-iid protocol)
# ---------------------------------------------------------------------------

def dirichlet_partition(labels: np.ndarray, n_nodes: int, alpha: float,
                        seed: int = 0) -> list:
    """Partition a labelled pool across nodes with Dirichlet(alpha) class
    proportions (Hsu et al.): for each class, sample p ~ Dir(alpha * 1_n)
    and deal that class's examples to nodes in proportion p.  Every example
    is assigned to exactly one node; every node receives at least one
    example (the emptiest node steals from the fullest if a draw starves
    it).  Returns a list of ``n_nodes`` index arrays.
    """
    labels = np.asarray(labels)
    rng = np.random.default_rng(np.random.SeedSequence((seed, 0xD117)))
    parts = [[] for _ in range(n_nodes)]
    for cls in np.unique(labels):
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        p = rng.dirichlet([alpha] * n_nodes)
        cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
        for node, chunk in enumerate(np.split(idx, cuts)):
            parts[node].extend(chunk.tolist())
    for node in range(n_nodes):  # no node may be empty
        if not parts[node]:
            donor = int(np.argmax([len(p) for p in parts]))
            parts[node].append(parts[donor].pop())
    return [np.sort(np.asarray(p, dtype=int)) for p in parts]


def logreg_dataset_dirichlet(n_nodes: int, m: int, d: int, *, alpha: float,
                             margin: float = 1.0, seed: int = 0):
    """§6-style binary data partitioned by :func:`dirichlet_partition`
    instead of the fixed 80/20 split: the label skew per node is governed
    by ``alpha`` (small = near-single-class nodes).  Each node holds ``m``
    samples drawn with replacement from its Dirichlet share so shapes stay
    (n_nodes, m, d) / (n_nodes, m) like :func:`logreg_dataset`.
    """
    rng = np.random.default_rng(seed)
    total = n_nodes * m
    w_star = rng.normal(size=d) / np.sqrt(d)
    y_all = np.where(rng.random(total) < 0.5, 1.0, -1.0)
    base = rng.normal(size=(total, d)).astype(np.float32)
    proj = base @ w_star
    base += np.outer((margin * y_all - proj) * 0.9, w_star) / (w_star @ w_star)
    parts = dirichlet_partition(y_all, n_nodes, alpha, seed=seed)
    feats = np.zeros((n_nodes, m, d), np.float32)
    labels = np.zeros((n_nodes, m), np.float32)
    for i, part in enumerate(parts):
        take = rng.choice(part, size=m, replace=True)
        feats[i] = base[take]
        labels[i] = y_all[take]
    return jnp.asarray(feats), jnp.asarray(labels)


# ---------------------------------------------------------------------------
# Paper §6: heterogeneous logistic-regression data
# ---------------------------------------------------------------------------

def logreg_dataset(n_nodes: int, m: int, d: int, *, positive_frac: float = 0.8,
                   margin: float = 1.0, seed: int = 0):
    """Synthetic linearly-separable-ish binary data, partitioned so that the
    first half of the nodes hold ``positive_frac`` positive datapoints and
    the second half the mirror (the paper's 80/20 protocol).

    Returns (H, y): H (n_nodes, m, d) features, y (n_nodes, m) in {-1, +1}.
    """
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=d) / np.sqrt(d)
    feats = np.zeros((n_nodes, m, d), np.float32)
    labels = np.zeros((n_nodes, m), np.float32)
    for i in range(n_nodes):
        frac = positive_frac if i < n_nodes // 2 else 1.0 - positive_frac
        n_pos = int(round(frac * m))
        y = np.concatenate([np.ones(n_pos), -np.ones(m - n_pos)])
        rng.shuffle(y)
        base = rng.normal(size=(m, d)).astype(np.float32)
        # push features to the correct side of the separator + noise
        proj = base @ w_star
        base += np.outer((margin * y - proj) * 0.9, w_star) / (w_star @ w_star)
        feats[i] = base
        labels[i] = y
    return jnp.asarray(feats), jnp.asarray(labels)


def logreg_loss_and_grad(rho: float):
    """Loss/gradient factory for the §6 objective:
    f_i(x) = mean_j ln(1 + exp(-y_ij <h_ij, x>)) + rho * sum_k x_k^2/(1+x_k^2).
    """

    def loss_i(x, H_i, y_i):
        z = -y_i * (H_i @ x)
        data = jnp.mean(jnp.logaddexp(0.0, z))
        reg = rho * jnp.sum(x ** 2 / (1.0 + x ** 2))
        return data + reg

    def full_grad(xs, H, y):
        """xs: (n, d) stacked models -> per-node full-batch gradients."""
        return jax.vmap(jax.grad(loss_i))(xs, H, y)

    def stochastic_grad(xs, H, y, key, batch: int):
        """Minibatch oracle: sample `batch` indices per node."""
        n, m, d = H.shape
        idx = jax.random.randint(key, (n, batch), 0, m)
        Hb = jnp.take_along_axis(H, idx[..., None], axis=1)
        yb = jnp.take_along_axis(y, idx, axis=1)
        return jax.vmap(jax.grad(loss_i))(xs, Hb, yb)

    def global_loss(x, H, y):
        n = H.shape[0]
        return jnp.mean(jax.vmap(lambda Hi, yi: loss_i(x, Hi, yi))(H, y))

    def global_grad_norm_sq(x, H, y):
        g = jax.grad(lambda xx: global_loss(xx, H, y))(x)
        return jnp.sum(g ** 2)

    return loss_i, full_grad, stochastic_grad, global_loss, global_grad_norm_sq
