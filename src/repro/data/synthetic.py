"""Data pipeline.

Two producers:

* ``TokenStream`` — deterministic synthetic LM token batches, shaped for the
  decentralized trainer: (n_nodes, R, batch, seq) so each node's R gradient
  accumulation rounds see distinct microbatches (Assumption 2's independent
  oracle queries).  Per-node PRNG folding keeps node i's stream independent
  of n or the host count.

* ``logreg_dataset`` — the paper's §6 protocol: binary classification data
  partitioned *heterogeneously* (a half of the nodes hold 80% positive
  samples, the other half 80% negative).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    n_nodes: int
    rounds: int            # R microbatches per step
    batch: int             # per-node, per-round sequences
    seq: int
    seed: int = 0
    active_vocab: int = 0          # 0 = full vocab; else restrict to first k
                                   # tokens (learnable low-entropy stream)
    arch_type: str = "dense"
    d_model: int = 0
    frontend_tokens: int = 0
    encoder_seq: int = 0

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        shape = (self.n_nodes, self.rounds, self.batch, self.seq)
        hi = self.active_vocab or self.vocab_size
        tokens = jax.random.randint(key, shape, 0, hi, jnp.int32)
        out = {"tokens": tokens}
        if self.arch_type == "vlm":
            kp = jax.random.fold_in(key, 1)
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                kp, shape[:3] + (self.frontend_tokens, self.d_model))
            out["tokens"] = tokens[..., :self.seq - self.frontend_tokens]
        elif self.arch_type == "audio":
            kp = jax.random.fold_in(key, 2)
            out["frames"] = 0.02 * jax.random.normal(
                kp, shape[:3] + (self.encoder_seq, self.d_model))
        return out


def token_stream_for(cfg, n_nodes: int, rounds: int, batch: int, seq: int,
                     seed: int = 0, active_vocab: int = 0) -> TokenStream:
    return TokenStream(vocab_size=cfg.vocab_size, n_nodes=n_nodes,
                       rounds=rounds, batch=batch, seq=seq, seed=seed,
                       active_vocab=active_vocab,
                       arch_type=cfg.arch_type, d_model=cfg.d_model,
                       frontend_tokens=cfg.frontend_tokens,
                       encoder_seq=cfg.encoder_seq)


# ---------------------------------------------------------------------------
# Paper §6: heterogeneous logistic-regression data
# ---------------------------------------------------------------------------

def logreg_dataset(n_nodes: int, m: int, d: int, *, positive_frac: float = 0.8,
                   margin: float = 1.0, seed: int = 0):
    """Synthetic linearly-separable-ish binary data, partitioned so that the
    first half of the nodes hold ``positive_frac`` positive datapoints and
    the second half the mirror (the paper's 80/20 protocol).

    Returns (H, y): H (n_nodes, m, d) features, y (n_nodes, m) in {-1, +1}.
    """
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=d) / np.sqrt(d)
    feats = np.zeros((n_nodes, m, d), np.float32)
    labels = np.zeros((n_nodes, m), np.float32)
    for i in range(n_nodes):
        frac = positive_frac if i < n_nodes // 2 else 1.0 - positive_frac
        n_pos = int(round(frac * m))
        y = np.concatenate([np.ones(n_pos), -np.ones(m - n_pos)])
        rng.shuffle(y)
        base = rng.normal(size=(m, d)).astype(np.float32)
        # push features to the correct side of the separator + noise
        proj = base @ w_star
        base += np.outer((margin * y - proj) * 0.9, w_star) / (w_star @ w_star)
        feats[i] = base
        labels[i] = y
    return jnp.asarray(feats), jnp.asarray(labels)


def logreg_loss_and_grad(rho: float):
    """Loss/gradient factory for the §6 objective:
    f_i(x) = mean_j ln(1 + exp(-y_ij <h_ij, x>)) + rho * sum_k x_k^2/(1+x_k^2).
    """

    def loss_i(x, H_i, y_i):
        z = -y_i * (H_i @ x)
        data = jnp.mean(jnp.logaddexp(0.0, z))
        reg = rho * jnp.sum(x ** 2 / (1.0 + x ** 2))
        return data + reg

    def full_grad(xs, H, y):
        """xs: (n, d) stacked models -> per-node full-batch gradients."""
        return jax.vmap(jax.grad(loss_i))(xs, H, y)

    def stochastic_grad(xs, H, y, key, batch: int):
        """Minibatch oracle: sample `batch` indices per node."""
        n, m, d = H.shape
        idx = jax.random.randint(key, (n, batch), 0, m)
        Hb = jnp.take_along_axis(H, idx[..., None], axis=1)
        yb = jnp.take_along_axis(y, idx, axis=1)
        return jax.vmap(jax.grad(loss_i))(xs, Hb, yb)

    def global_loss(x, H, y):
        n = H.shape[0]
        return jnp.mean(jax.vmap(lambda Hi, yi: loss_i(x, Hi, yi))(H, y))

    def global_grad_norm_sq(x, H, y):
        g = jax.grad(lambda xx: global_loss(xx, H, y))(x)
        return jnp.sum(g ** 2)

    return loss_i, full_grad, stochastic_grad, global_loss, global_grad_norm_sq
