from .synthetic import (  # noqa: F401
    TokenStream,
    dirichlet_partition,
    logreg_dataset,
    logreg_dataset_dirichlet,
    logreg_loss_and_grad,
    token_stream_for,
)
