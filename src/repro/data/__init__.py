from .synthetic import TokenStream, logreg_dataset, logreg_loss_and_grad, token_stream_for  # noqa: F401
