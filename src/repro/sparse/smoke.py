"""Plan-only scale smoke: prove realize -> degrade -> lower -> restage
cost scales with *edges*, not nodes.

The sparse engine's contract is that no stage of the plan path touches an
(n, n) object, so running the identical pipeline at 10k and 100k nodes
with the same per-round cohort ``k`` must cost about the same wall time
(the work is O(rounds * k^2) realization + O(edges) staging at both
sizes).  CI runs this as a fast lane cell:

    PYTHONPATH=src python -m repro.sparse.smoke

No mixing happens — this is the staging half only, so it stays in the
seconds range even at 100k nodes.
"""

from __future__ import annotations

import time

from ..sim import channel as sim_channel
from .realize import realize_sparse_schedule
from .sampled import sampled_weight_schedule


def _stage(n: int, k: int, rounds: int, seed: int) -> tuple[float, int]:
    """One full staging pass at ``n`` nodes; returns (seconds, edges)."""
    t0 = time.perf_counter()
    sched = sampled_weight_schedule(n, k, horizon=rounds, seed=seed)
    real = realize_sparse_schedule(
        sched, [sim_channel.BernoulliDropChannel(0.2, seed=7)])
    plan = real.plan()
    plan.tensors()
    return time.perf_counter() - t0, int(plan.edges_per_round.sum())


def plan_scale_smoke(n_small: int = 10_000, n_big: int = 100_000,
                     k: int = 256, rounds: int = 16, seed: int = 0,
                     factor: float = 5.0) -> dict:
    """Stage the same sampled scenario at ``n_small`` and ``n_big`` nodes
    and assert the wall-time ratio stays below ``factor`` (a 10x node
    count would be ~100x under any O(n^2) dependence; ``factor`` leaves
    generous room for timer noise while still catching densification)."""
    _stage(256, 16, 2, seed)  # warm imports/caches out of the measurement
    t_small, e_small = _stage(n_small, k, rounds, seed)
    t_big, e_big = _stage(n_big, k, rounds, seed)
    ratio = t_big / max(t_small, 1e-9)
    out = {"n_small": n_small, "n_big": n_big, "k": k, "rounds": rounds,
           "sec_small": round(t_small, 3), "sec_big": round(t_big, 3),
           "edges_small": e_small, "edges_big": e_big,
           "ratio": round(ratio, 2)}
    assert ratio < factor, (
        f"staging {n_big} nodes took {ratio:.1f}x the {n_small}-node time "
        f"(limit {factor}x): some stage is scaling with n, not edges "
        f"— {out}")
    return out


if __name__ == "__main__":
    res = plan_scale_smoke()
    print(f"ok   sparse plan restage scales with edges: "
          f"{res['n_big']:,} nodes in {res['sec_big']}s vs "
          f"{res['n_small']:,} in {res['sec_small']}s "
          f"(ratio {res['ratio']}x, edges {res['edges_big']:,} vs "
          f"{res['edges_small']:,})")
