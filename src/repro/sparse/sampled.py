"""Sampled-client mobility topologies: k of n nodes participate per round.

The cross-device federated regime the paper frames as a time-varying
network: a fleet of n (up to 10^6) devices of which only a sampled cohort
of k check in each round.  Every draw is a pure function of ``(seed, t)``
(plus node/leg ids), like the dense mobility schedules — but via the
random-access counter streams of :mod:`repro.sim.hashrand`, because at
n = 10^6 we may only do O(k) work per round:

* **cohort**    — k distinct node ids via Floyd's sampling algorithm,
  O(k) time and memory (no O(n) permutation);
* **positions** — random-waypoint motion evaluated only at the sampled
  ids: waypoints are hashed per ``(node, leg)``, so any node's position at
  any round is random-access, O(1);
* **edges**     — unit-disk graph among the k sampled positions (O(k^2)
  pairwise test, n-independent) with Metropolis weights on the sampled
  subgraph, giving a doubly stochastic round (Assumption 3; non-sampled
  nodes sit on the implied diagonal with weight 1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..sim import hashrand
from .plan import SparseRound, _as_edge_arrays
from .schedule import SparseWeightSchedule

_SAMPLE_TAG = 0x5E1    # per-round participant draw
_WAYPOINT_X_TAG = 0x5E2  # per-(node, leg) waypoint coordinates
_WAYPOINT_Y_TAG = 0x5E3


def sample_participants(n: int, k: int, seed: int, t: int) -> np.ndarray:
    """k distinct ids from [0, n) — Floyd's algorithm, O(k) not O(n)."""
    rng = np.random.default_rng(np.random.SeedSequence((seed, _SAMPLE_TAG, t)))
    chosen = set()
    for j in range(n - k, n):
        v = int(rng.integers(0, j + 1))
        chosen.add(j if v in chosen else v)
    return np.sort(np.fromiter(chosen, dtype=np.int64, count=k))


def waypoint_positions(nodes: np.ndarray, t: int, *, seed: int,
                       leg_rounds: int) -> np.ndarray:
    """(len(nodes), 2) random-waypoint positions at round t, random-access:
    each node interpolates between hashed per-(node, leg) waypoints."""
    leg, r = divmod(t, leg_rounds)
    frac = r / leg_rounds
    ax = hashrand.counter_uniform(seed, _WAYPOINT_X_TAG, nodes, leg)
    ay = hashrand.counter_uniform(seed, _WAYPOINT_Y_TAG, nodes, leg)
    bx = hashrand.counter_uniform(seed, _WAYPOINT_X_TAG, nodes, leg + 1)
    by = hashrand.counter_uniform(seed, _WAYPOINT_Y_TAG, nodes, leg + 1)
    return np.stack([ax + (bx - ax) * frac, ay + (by - ay) * frac], axis=1)


def metropolis_edges(nodes: np.ndarray, adj: np.ndarray):
    """Metropolis-Hastings weights on a sampled subgraph.

    ``adj`` is the (k, k) boolean adjacency among ``nodes`` (diagonal
    ignored); returns global-id edge arrays with
    ``w_ij = 1 / (1 + max(deg_i, deg_j))`` — symmetric, row sums < 1, so
    the implied-diagonal round is doubly stochastic.
    """
    off = adj & ~np.eye(len(nodes), dtype=bool)
    deg = off.sum(axis=1)
    ii, jj = np.nonzero(off)
    w = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    return _as_edge_arrays(nodes[jj], nodes[ii], w)


@dataclasses.dataclass(frozen=True)
class SampledMobilitySchedule:
    """``random-sampled``: per-round cohort + unit-disk + Metropolis.

    Non-periodic (``period = None``): every round is a fresh ``(seed, t)``
    draw; consumers materialize a horizon window via :func:`materialize`.
    """

    n: int
    sample_k: int
    radius: float = 0.45
    leg_rounds: int = 8
    seed: int = 0

    period = None

    def __post_init__(self):
        if not 2 <= self.sample_k <= self.n:
            raise ValueError(
                f"random-sampled needs 2 <= sample_k <= n; got "
                f"k={self.sample_k}, n={self.n}")

    def round(self, t: int) -> SparseRound:
        nodes = sample_participants(self.n, self.sample_k, self.seed, t)
        pos = waypoint_positions(nodes, t, seed=self.seed,
                                 leg_rounds=self.leg_rounds)
        diff = pos[:, None, :] - pos[None, :, :]
        adj = (diff ** 2).sum(-1) <= self.radius ** 2
        src, dst, w = metropolis_edges(nodes, adj)
        return SparseRound(self.n, src, dst, w)

    def __call__(self, t: int) -> np.ndarray:
        return self.round(t).as_dense()


def sampled_weight_schedule(n: int, sample_k: int, *, radius: float = 0.45,
                            leg_rounds: int = 8, seed: int = 0,
                            horizon: int) -> SparseWeightSchedule:
    """Materialize a ``horizon``-round window of the ideal (fault-free)
    sampled schedule — O(horizon * k^2) total, n-independent."""
    gen = SampledMobilitySchedule(n, sample_k, radius=radius,
                                  leg_rounds=leg_rounds, seed=seed)
    return SparseWeightSchedule(tuple(gen.round(t) for t in range(horizon)))
