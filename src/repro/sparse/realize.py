"""Fault realization over edge lists — O(edges) per round.

The sparse counterpart of :func:`repro.sim.faults.realize_weight_schedule`:
each round's edges are filtered by the channel/fault models' ``edge_mask``
streams (:mod:`repro.sim.channel`, :mod:`repro.sim.faults`), and the
Laplacian edge form makes repair free — a dropped edge's weight returns to
both endpoints' diagonals by construction (see
:func:`repro.sim.faults.repair_edges`).  No dense matrix is ever built.
"""

from __future__ import annotations

from typing import Sequence

from ..sim import faults as sim_faults
from .schedule import SparseWeightSchedule


def realize_sparse_schedule(ideal, models: Sequence,
                            rounds: int | None = None,
                            t0: int = 0) -> SparseWeightSchedule:
    """Materialize the realized post-fault window of a sparse schedule.

    ``ideal`` is anything with ``round(t) -> SparseRound`` (a
    :class:`~repro.sparse.schedule.SparseWeightSchedule` window or a
    non-periodic generator like
    :class:`~repro.sparse.sampled.SampledMobilitySchedule`).
    """
    if rounds is None:
        rounds = getattr(ideal, "period", None)
        if rounds is None:
            raise ValueError("non-periodic schedule requires rounds=<window>")
    out = []
    for r in range(rounds):
        t = t0 + r
        rd = ideal.round(t)
        if models and rd.edges:
            keep = sim_faults.combined_edge_mask(models, t, rd.src, rd.dst)
            rd = rd.filter(keep)
        out.append(rd)
    return SparseWeightSchedule(tuple(out))
