"""Edge-list gossip rounds and plans (the sparse scenario representation).

A gossip matrix under Assumption 3 is row-stochastic, so its diagonal is
redundant: storing only the off-diagonal entries as COO edges pins the
whole matrix.  We keep rounds in *Laplacian form*,

    W = I - diag(rowsum(w)) + scatter(w),      w[e] = W[dst[e], src[e]] > 0,

and mix as ``z = x + sum_e w[e] * (x[src[e]] - x[dst[e]]) -> dst[e]``.
This buys three O(edges) properties the dense (n, n) representation
cannot offer past a few hundred nodes:

* **realize** — a round is just its edge arrays; no n x n materialization;
* **repair**  — dropping an edge returns its weight to both endpoints'
  diagonals *by construction* (exactly the lazy repair of
  :func:`repro.sim.faults.repair_weights`), so fault realization is a
  boolean filter over edges;
* **classify** — empty/matching/sparse kinds fall out of degree counts.

Symmetric edge weights (both directed entries stored, equal weights) make
the round doubly stochastic, i.e. Assumption 3 minus the spectral bound.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

# Above this node count, materializing dense (n, n) matrices from a sparse
# round is considered a bug; as_dense()/stacked() raise instead of thrashing.
DENSE_GUARD = 8192


def _as_edge_arrays(src, dst, w):
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    w = np.asarray(w, dtype=np.float64)
    order = np.lexsort((src, dst))  # canonical: sorted by (dst, src)
    return src[order], dst[order], w[order]


@dataclasses.dataclass(frozen=True)
class SparseRound:
    """One gossip round as directed COO edges in Laplacian form.

    ``w[e]`` is the off-diagonal weight ``W[dst[e], src[e]]``; the diagonal
    is implied by row-stochasticity (``W[i, i] = 1 - sum_j W[i, j]``).
    ``diag`` optionally pins the exact diagonal of a round extracted from a
    dense matrix so ``as_dense()`` reconstructs it bit-exactly; native
    sparse rounds leave it ``None`` (implied diagonal).
    """

    n: int
    src: np.ndarray            # (E,) int32 — sender j of entry W[dst, src]
    dst: np.ndarray            # (E,) int32 — receiver i
    w: np.ndarray              # (E,) float64 — off-diagonal weight
    diag: np.ndarray | None = None  # (n,) float64, only for dense-extracted rounds

    @property
    def edges(self) -> int:
        return int(self.src.size)

    @functools.cached_property
    def participants(self) -> np.ndarray:
        """Sorted unique node ids touched by any edge this round."""
        return np.unique(np.concatenate([self.src, self.dst])) \
            if self.src.size else np.empty(0, dtype=np.int32)

    @functools.cached_property
    def senders(self) -> int:
        """Number of distinct transmitting nodes (unique ``src``)."""
        return int(np.unique(self.src).size)

    @functools.cached_property
    def kind(self) -> str:
        """empty | matching | sparse — O(E log E) classification."""
        if self.src.size == 0:
            return "empty"
        recv, counts = np.unique(self.dst, return_counts=True)
        if (counts == 1).all():
            # degree <= 1 everywhere: matching iff the peer map is an
            # involution (i <-> j both present)
            order = np.argsort(self.dst)
            d, s = self.dst[order], self.src[order]
            back = np.searchsorted(d, s)
            ok = (back < d.size) & (d[np.minimum(back, d.size - 1)] == s)
            if ok.all() and np.array_equal(s[back], d):
                return "matching"
        return "sparse"

    def filter(self, keep: np.ndarray) -> "SparseRound":
        """Drop edges where ``keep`` is False — O(E) fault repair.

        In Laplacian form a dropped edge's weight returns to both
        endpoints' diagonals automatically, which is exactly
        :func:`repro.sim.faults.repair_weights` without densification.
        The pinned ``diag`` is discarded: the repaired diagonal is the
        implied one.
        """
        keep = np.asarray(keep, dtype=bool)
        return SparseRound(self.n, self.src[keep], self.dst[keep],
                           self.w[keep])

    def as_dense(self) -> np.ndarray:
        if self.n > DENSE_GUARD:
            raise ValueError(
                f"refusing to densify a SparseRound with n={self.n} "
                f"(> {DENSE_GUARD}); use the edge-list operations instead")
        W = np.zeros((self.n, self.n), dtype=np.float64)
        W[self.dst, self.src] = self.w
        if self.diag is not None:
            W[np.arange(self.n), np.arange(self.n)] = self.diag
        else:
            rowsum = np.bincount(self.dst, weights=self.w, minlength=self.n)
            W[np.arange(self.n), np.arange(self.n)] = 1.0 - rowsum
        return W

    def apply(self, x: np.ndarray) -> np.ndarray:
        """Host-side numpy mix ``W @ x`` in O(edges * dim)."""
        x = np.asarray(x, dtype=np.float64)
        if self.src.size == 0:
            return x.copy()
        contrib = self.w[:, None] * (x[self.src] - x[self.dst])
        out = x.copy()
        np.add.at(out, self.dst, contrib)
        return out

    def check(self, atol: float = 1e-8) -> None:
        """Assumption-3 invariants that are checkable in O(E log E):
        nonnegative weights, symmetric weight pairs (=> doubly stochastic),
        implied diagonal in [0, 1], and a consistent pinned diagonal."""
        if self.src.size == 0:
            return
        if (self.w < -atol).any():
            raise ValueError("negative edge weight")
        if (self.src == self.dst).any():
            raise ValueError("self-loop stored as an edge (diagonal is implied)")
        order_f = np.lexsort((self.src, self.dst))
        order_b = np.lexsort((self.dst, self.src))
        if not (np.array_equal(self.dst[order_f], self.src[order_b])
                and np.array_equal(self.src[order_f], self.dst[order_b])
                and np.allclose(self.w[order_f], self.w[order_b], atol=atol)):
            raise ValueError("edge weights are not symmetric "
                             "(round would not be doubly stochastic)")
        parts = self.participants
        rowsum = np.bincount(self.dst, weights=self.w,
                             minlength=int(parts[-1]) + 1)[parts]
        if (rowsum > 1.0 + atol).any():
            raise ValueError("implied diagonal negative (row sum > 1)")
        if self.diag is not None:
            implied = 1.0 - np.bincount(self.dst, weights=self.w,
                                        minlength=self.n)
            if not np.allclose(self.diag, implied, atol=max(atol, 1e-7)):
                raise ValueError("pinned diagonal inconsistent with row sums")


def round_from_dense(W: np.ndarray, atol: float = 1e-12) -> SparseRound:
    """Extract the off-diagonal edges of a dense gossip matrix.

    Pins the exact diagonal so ``as_dense()`` round-trips bit-exactly.
    """
    W = np.asarray(W, dtype=np.float64)
    n = W.shape[0]
    off = np.abs(W) > atol
    np.fill_diagonal(off, False)
    dst, src = np.nonzero(off)
    s, d, w = _as_edge_arrays(src, dst, W[dst, src])
    return SparseRound(n, s, d, w, diag=np.ascontiguousarray(np.diag(W)))


@dataclasses.dataclass(frozen=True)
class SparseGossipPlan:
    """A window of sparse rounds as one concatenated COO edge list.

    ``offsets`` has ``period + 1`` entries; round r owns the slice
    ``[offsets[r], offsets[r+1])`` of ``src``/``dst``/``w`` (the "per-round
    segment offsets" of the representation).  ``tensors()`` stages the plan
    as padded per-round device arrays; :meth:`make_mixer` returns the same
    ``mix_fn(tensors, t0, rounds, tree)`` interface the dense
    :func:`repro.core.algorithms.make_plan_mixer` exposes, so
    ``plan_step``/``run_algorithm`` consume either plan via duck typing.
    """

    n: int
    src: np.ndarray       # (Etot,) int32
    dst: np.ndarray       # (Etot,) int32
    w: np.ndarray         # (Etot,) float64
    offsets: np.ndarray   # (period + 1,) int64
    diags: tuple = ()     # per-round pinned diagonals (or None), optional

    is_edge_plan = True

    @classmethod
    def from_rounds(cls, rounds) -> "SparseGossipPlan":
        rounds = tuple(rounds)
        if not rounds:
            raise ValueError("plan needs at least one round")
        n = rounds[0].n
        offsets = np.zeros(len(rounds) + 1, dtype=np.int64)
        np.cumsum([r.edges for r in rounds], out=offsets[1:])
        cat = lambda xs, dt: (np.concatenate(xs).astype(dt) if offsets[-1]
                              else np.empty(0, dtype=dt))
        return cls(
            n=n,
            src=cat([r.src for r in rounds], np.int32),
            dst=cat([r.dst for r in rounds], np.int32),
            w=cat([r.w for r in rounds], np.float64),
            offsets=offsets,
            diags=tuple(r.diag for r in rounds),
        )

    @property
    def period(self) -> int:
        return int(self.offsets.size - 1)

    @functools.cached_property
    def edges_per_round(self) -> np.ndarray:
        return np.diff(self.offsets)

    def round(self, r: int) -> SparseRound:
        lo, hi = int(self.offsets[r]), int(self.offsets[r + 1])
        diag = self.diags[r] if self.diags else None
        return SparseRound(self.n, self.src[lo:hi], self.dst[lo:hi],
                           self.w[lo:hi], diag=diag)

    @functools.cached_property
    def kinds(self) -> tuple:
        return tuple(self.round(r).kind for r in range(self.period))

    # run_algorithm/bind_step read this to pick jit static args; the sparse
    # plan always stages uniform padded rounds -> traced-t dispatch.
    dispatch = "dynamic"

    def validate(self) -> "SparseGossipPlan":
        for r in range(self.period):
            self.round(r).check()
        return self

    def as_dense(self, validate: bool = False):
        """Reconstruct the dense :class:`repro.core.gossip.GossipPlan` this
        plan represents (small-n equivalence checks; raises past the
        dense guard)."""
        from ..core import gossip as _gossip
        mats = [self.round(r).as_dense() for r in range(self.period)]
        rounds = tuple(_gossip.plan_round(W, sparse=False) for W in mats)
        plan = _gossip.GossipPlan(rounds)
        if validate:
            plan.validate()
        return plan

    def tensors(self) -> dict:
        """Stage as padded per-round numpy arrays (one jnp.asarray away
        from device).  Padding is inert by construction: pad edges carry
        ``w = 0`` (zero contribution) and pad slots carry ``n`` (dropped by
        the out-of-bounds scatter mode).

        Keys: ``esrc``/``edst``/``ew`` — (P, Emax) edge arrays for the
        scatter mixer; ``seg``/``slots`` — (P, Emax)/(P, Smax) compacted
        destination segments for the Pallas segment-sum path.
        """
        P = self.period
        emax = max(1, int(self.edges_per_round.max()) if P else 1)
        esrc = np.zeros((P, emax), dtype=np.int32)
        edst = np.zeros((P, emax), dtype=np.int32)
        ew = np.zeros((P, emax), dtype=np.float32)
        seg = np.zeros((P, emax), dtype=np.int32)
        smax = 1
        slot_rows = []
        for r in range(P):
            rd = self.round(r)
            e = rd.edges
            esrc[r, :e] = rd.src
            edst[r, :e] = rd.dst
            ew[r, :e] = rd.w
            slots = np.unique(rd.dst) if e else np.empty(0, np.int32)
            seg[r, :e] = np.searchsorted(slots, rd.dst) if e else 0
            slot_rows.append(slots)
            smax = max(smax, slots.size)
        slots_arr = np.full((P, smax), self.n, dtype=np.int32)
        for r, s in enumerate(slot_rows):
            slots_arr[r, :s.size] = s
        return {"esrc": esrc, "edst": edst, "ew": ew,
                "seg": seg, "slots": slots_arr}

    def make_mixer(self, *, mesh=None, axis="data", mode=None,
                   use_pallas=False, interpret="auto"):
        """Build ``mix_fn(tensors, t0, rounds, tree)`` for this plan — the
        sparse counterpart of :func:`repro.core.algorithms.make_plan_mixer`.

        The default path scatter-adds edge contributions per round inside a
        ``lax.scan``; ``use_pallas=True`` routes 2-D leaves through
        :func:`repro.kernels.ops.sparse_gossip_mix` (segment-sum kernel).
        """
        del mesh, axis, mode  # single-host edge plan: no collective lowering
        import jax
        import jax.numpy as jnp

        from ..core.algorithms import sparse_mix
        from ..kernels import ops as kops

        def mix_fn(tensors, t0, rounds, tree):
            idxs = (t0 + jnp.arange(rounds)) % self.period
            take = lambda k: jnp.take(tensors[k], idxs, axis=0)
            if use_pallas:
                xs = (take("esrc"), take("edst"), take("ew"),
                      take("seg"), take("slots"))

                def body(z, sdw):
                    s, d, wgt, sg, sl = sdw
                    z = jax.tree.map(
                        lambda leaf: kops.sparse_gossip_mix(
                            leaf.reshape(leaf.shape[0], -1), s, d, wgt, sg,
                            sl, use_pallas=True,
                            interpret=interpret).reshape(leaf.shape),
                        z)
                    return z, None
            else:
                xs = (take("esrc"), take("edst"), take("ew"))

                def body(z, sdw):
                    return sparse_mix(sdw[0], sdw[1], sdw[2], z), None

            out, _ = jax.lax.scan(body, tree, xs)
            return out

        mix_fn.dispatch = "dynamic"
        return mix_fn
