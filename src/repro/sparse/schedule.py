"""Materialized windows of sparse gossip rounds.

:class:`SparseWeightSchedule` is the edge-list counterpart of
:class:`repro.core.gossip.WeightSchedule`: a finite window of
:class:`~repro.sparse.plan.SparseRound` objects exposing the same
``period`` / ``__call__`` / ``structure`` / ``stacked`` / ``plan``
interface, so :func:`repro.core.driver.run_algorithm` and
:mod:`repro.exp.build` consume either via duck typing.  Dense
materialization (``__call__``/``stacked``) exists only for small-n
equivalence checks and the host ``gossip_impl="dense"`` path; it raises
past :data:`repro.sparse.plan.DENSE_GUARD`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import topology as topo
from .plan import DENSE_GUARD, SparseGossipPlan, SparseRound, round_from_dense


@dataclasses.dataclass(frozen=True)
class SparseWeightSchedule:
    """A finite window of sparse rounds; round t is ``rounds[t % period]``."""

    rounds: tuple  # tuple[SparseRound, ...]

    is_sparse = True

    def __post_init__(self):
        if not self.rounds:
            raise ValueError("schedule needs at least one round")

    @property
    def n(self) -> int:
        return self.rounds[0].n

    @property
    def period(self) -> int:
        return len(self.rounds)

    def round(self, t: int) -> SparseRound:
        return self.rounds[t % len(self.rounds)]

    @property
    def edges_per_round(self) -> np.ndarray:
        """Directed off-diagonal edge count of each round in the window."""
        return np.array([r.edges for r in self.rounds], dtype=np.int64)

    @property
    def senders_per_round(self) -> np.ndarray:
        """Participating sender count of each round in the window."""
        return np.array([r.senders for r in self.rounds], dtype=np.int64)

    # -- dense compatibility surface (small n only) ---------------------
    def __call__(self, t: int) -> np.ndarray:
        return self.round(t).as_dense()

    def structure(self, t: int) -> topo.RoundStructure:
        rd = self.round(t)
        if rd.kind == "empty":
            return topo.RoundStructure("empty")
        if rd.kind == "matching" and rd.n <= DENSE_GUARD:
            # the dense planner wants the full involution; only worth
            # materializing at small n
            perm = np.arange(rd.n)
            perm[rd.dst] = rd.src
            return topo.RoundStructure("matching",
                                       perm=tuple(int(p) for p in perm))
        return topo.RoundStructure("dense")

    def stacked(self, t0: int, rounds: int, dtype=np.float32) -> np.ndarray:
        if self.n > DENSE_GUARD:
            raise ValueError(
                f"refusing to stack dense matrices for n={self.n} "
                f"(> {DENSE_GUARD}); run this schedule with "
                "gossip_impl='auto' so it stays in edge form")
        return np.stack([self(t0 + r) for r in range(rounds)]).astype(dtype)

    def plan(self, t0: int = 0, rounds: int | None = None, *,
             validate: bool = True, pods=None, sparse=None,
             personalized: bool = False) -> SparseGossipPlan:
        """Lower a window to a :class:`SparseGossipPlan` in O(edges).

        ``pods``/``sparse`` are accepted for interface parity with the
        dense planner and ignored (an edge plan has no two-level lowering
        and is already sparse).
        """
        del pods, sparse
        if personalized:
            raise ValueError("personalized rounds stage per-node dense "
                             "weight rows; the edge-form plan cannot "
                             "lower them")
        rounds = self.period if rounds is None else rounds
        plan = SparseGossipPlan.from_rounds(
            self.round(t0 + r) for r in range(rounds))
        return plan.validate() if validate else plan


def from_weight_schedule(ws, t0: int = 0,
                         rounds: int | None = None) -> SparseWeightSchedule:
    """Convert a window of a dense :class:`repro.core.gossip.WeightSchedule`
    (or any ``t -> (n, n)`` callable with a period) to edge form, pinning
    each round's exact diagonal for bit-exact reconstruction."""
    if rounds is None:
        rounds = getattr(ws, "period", None)
        if rounds is None:
            raise ValueError("non-periodic schedule requires rounds=<window>")
    return SparseWeightSchedule(tuple(
        round_from_dense(np.asarray(ws(t0 + r), dtype=np.float64))
        for r in range(rounds)))
