"""Sparse scenario engine: edge-list gossip plans and sampled clients.

The O(edges) counterpart of the dense planner stack, for scenarios past a
few hundred nodes (100k-1M node fleets with k sampled participants per
round).  See README "Sparse plans & client sampling".

* :mod:`repro.sparse.plan` — :class:`SparseRound` / :class:`SparseGossipPlan`
  (COO edges + per-round segment offsets, Laplacian form);
* :mod:`repro.sparse.schedule` — :class:`SparseWeightSchedule` windows with
  the dense-schedule duck-type surface;
* :mod:`repro.sparse.sampled` — the ``random-sampled`` topology family;
* :mod:`repro.sparse.realize` — O(edges) fault realization;
* :mod:`repro.sparse.telemetry` — power-iteration mixing proxies and
  participating-sender wire pricing.
"""

from .plan import (DENSE_GUARD, SparseGossipPlan, SparseRound,
                   round_from_dense)
from .realize import realize_sparse_schedule
from .sampled import SampledMobilitySchedule, sampled_weight_schedule
from .schedule import SparseWeightSchedule, from_weight_schedule
from .telemetry import SparseTelemetryRecorder, sparse_windowed_gap

__all__ = [
    "DENSE_GUARD",
    "SparseRound",
    "SparseGossipPlan",
    "SparseWeightSchedule",
    "SampledMobilitySchedule",
    "SparseTelemetryRecorder",
    "from_weight_schedule",
    "realize_sparse_schedule",
    "round_from_dense",
    "sampled_weight_schedule",
    "sparse_windowed_gap",
]
