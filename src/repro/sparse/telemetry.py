"""Edge-list telemetry: spectral-gap proxies and wire pricing in O(edges).

The dense :class:`repro.sim.telemetry.TelemetryRecorder` materializes each
realized round as an (n, n) float64 matrix and takes a dense SVD of the
window product — O(n^3) per record, impossible at 10^5+ nodes.  This
recorder keeps the identical ``record``/``dump`` interface and history
schema but computes everything from the edge lists:

* ``spectral_gap`` — power iteration on the window product restricted to
  the *participant* subspace (the union of nodes touched by any window
  edge), with the participant-mean deflated on each side.  At full
  participation this equals the dense ``1 - ||prod W - 11^T/n||_2``
  (pinned by tests); under client sampling the full-n gap is trivially 0
  (non-participants never move), so the participant-restricted contraction
  is the quantity that actually tracks mixing progress.
* ``bytes`` — per round, only *participating senders* (distinct ``src``
  ids of the realized edges) are priced.  The dense recorder already
  counts active rows; this is the same contract without densification.
* ``eff_diameter`` — ``None``: the all-pairs frontier propagation is
  inherently O(n^2) and is not approximated here.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from ..sim import telemetry as sim_telemetry


def sparse_windowed_gap(rounds, iters: int = 40, seed: int = 0) -> float:
    """1 - beta of the window product over the participant subspace.

    ``rounds`` is an ordered sequence of :class:`repro.sparse.plan.
    SparseRound`; beta is estimated as sqrt(lambda_max((P(I-J))^T P(I-J)))
    by power iteration, where P is the window product applied in O(edges)
    per round via scatter-adds and J is the mean over participants.  Each
    round is symmetric (Assumption 3), so P^T is the reversed window.
    """
    active = [r for r in rounds if r.edges]
    if not active:
        return 0.0  # no communication: the window does not mix at all
    parts = np.unique(np.concatenate(
        [np.concatenate([r.src, r.dst]) for r in active]))
    m = parts.size
    local = [(np.searchsorted(parts, r.src).astype(np.int64),
              np.searchsorted(parts, r.dst).astype(np.int64),
              r.w) for r in active]

    def _apply(v, seq):
        for ls, ld, w in seq:
            v = v + np.bincount(ld, weights=w * (v[ls] - v[ld]), minlength=m)
        return v

    rng = np.random.default_rng(seed)
    v = rng.standard_normal(m)
    lam = 0.0
    for _ in range(iters):
        v = v - v.mean()
        nv = np.linalg.norm(v)
        if nv < 1e-30:
            return 1.0  # window contracts deviations to numerical zero
        v = v / nv
        u = _apply(v, local)
        u = u - u.mean()
        y = _apply(u, list(reversed(local)))
        y = y - y.mean()
        lam = float(v @ y)
        v = y
    beta = float(np.sqrt(max(lam, 0.0)))
    return 1.0 - min(beta, 1.0)


class SparseTelemetryRecorder(sim_telemetry.TelemetryRecorder):
    """Drop-in recorder for :class:`repro.sparse.schedule.
    SparseWeightSchedule` — same hook signature, history schema, and
    ``dump`` format as the dense recorder."""

    def _round(self, r: int) -> tuple:
        hit = self._rounds.get(r) if self.cache else None
        if hit is None:
            rd = self.realized.round(r)
            hit = (rd, None, rd.kind)
            if self.cache:
                self._rounds[r] = hit
        return hit

    def _window_metrics(self, t: int) -> dict:
        lo = max(0, t - self.window)
        if t <= lo:
            return {"window": [lo, t], "spectral_gap": None,
                    "eff_diameter": None, "kinds": {}}
        floor = lo - self.delay * self.wps
        if self.cache:
            for r in [r for r in self._rounds if r < floor]:
                del self._rounds[r]
        rounds, kinds = [], {}
        for r in range(lo, t):
            rd, _, kind = self._round(r)
            rounds.append(rd)
            kinds[kind] = kinds.get(kind, 0) + 1
        out = {"window": [lo, t],
               "spectral_gap": round(sparse_windowed_gap(rounds), 6),
               "eff_diameter": None,
               "kinds": kinds}
        if self.delay:
            shift = self.delay * self.wps
            s_lo, s_t = max(0, lo - shift), max(0, t - shift)
            if s_t <= s_lo:
                out["stale_gap"] = None
            else:
                landed = [self._round(r)[0] for r in range(s_lo, s_t)]
                out["stale_gap"] = round(sparse_windowed_gap(landed), 6)
        return out

    def _step_bytes(self, k: int, t: int, state: Any) -> int:
        from ..core import compress

        if self._dim is None:
            leaves = jax.tree.leaves(state.x)
            n = leaves[0].shape[0]
            self._dim = sum(int(np.prod(l.shape)) for l in leaves) // n
        c = self.compression
        if c is None or k < c.warmup:
            per = compress.payload_bytes(self._dim, "none")
        else:
            per = compress.payload_bytes(self._dim, c.scheme, c.group)
        total = 0
        for r in range(max(0, t - self.wps), t):
            rd, _, _ = self._round(r)
            total += rd.senders * per  # only participating senders transmit
        return total
