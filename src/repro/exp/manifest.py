"""Reproducibility manifests: the fully-resolved spec JSON written next to
every run output (checkpoint / telemetry), and the mismatch check
``restore_or_warm`` applies when a run resumes from a checkpoint whose
manifest disagrees with the current spec.
"""

from __future__ import annotations

import json
import os
import warnings
from typing import Optional

from . import spec as S

MANIFEST_FORMAT = "repro.exp/manifest/v1"

# Run-shape fields that legitimately differ between a run and its restore
# continuation — excluded from the mismatch comparison.
_RESUMABLE_RUN_FIELDS = ("steps", "checkpoint", "restore", "telemetry",
                         "log_every", "eval_every")

# Whole sections that are observation-only: they never change the training
# trajectory, so a restore continuation may change them freely.
_NON_SCENARIO_SECTIONS = ("obs",)


def manifest_path(output_path: str) -> str:
    """The manifest sits next to its output: ``<output>.spec.json``."""
    return output_path + ".spec.json"


def resolved_manifest(spec: S.ExperimentSpec, *, realized: dict | None = None) -> dict:
    """The manifest payload: the FULL spec (defaults included, so the file
    is self-contained even if future defaults change), its hash, and the
    realized quantities a reader cannot derive from the spec alone (the
    materialized schedule period, rounds per step, horizon, plan kinds)."""
    return {
        "format": MANIFEST_FORMAT,
        "spec": S.to_dict(spec, elide_defaults=False),
        "spec_hash": S.spec_hash(spec),
        "realized": dict(realized or {}),
    }


def write_manifest(output_path: str, spec: S.ExperimentSpec, *,
                   realized: dict | None = None) -> str:
    path = manifest_path(output_path)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(resolved_manifest(spec, realized=realized), f, indent=1,
                  sort_keys=True)
    return path


def load_manifest(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    if d.get("format") != MANIFEST_FORMAT:
        raise ValueError(f"{path}: not a {MANIFEST_FORMAT} manifest "
                         f"(format={d.get('format')!r})")
    # strict round-trip: schema drift in the spec section fails here
    d["spec_parsed"] = S.from_dict(d["spec"])
    return d


def _comparable(spec: S.ExperimentSpec) -> dict:
    d = S.to_dict(spec, elide_defaults=False)
    for f in _RESUMABLE_RUN_FIELDS:
        d["run"].pop(f, None)
    for sec in _NON_SCENARIO_SECTIONS:
        d.pop(sec, None)
    return d


def diff_specs(a: S.ExperimentSpec, b: S.ExperimentSpec) -> list[str]:
    """Dotted paths of scenario-defining fields on which ``a`` and ``b``
    disagree (run-shape fields a restore continuation may change are
    ignored)."""
    da, db = _comparable(a), _comparable(b)
    out = []
    for section in da:
        for field in da[section]:
            if da[section][field] != db[section][field]:
                out.append(f"{section}.{field}")
    return sorted(out)


def check_restore_spec(restore_path: str,
                       spec: S.ExperimentSpec) -> Optional[list[str]]:
    """Compare ``spec`` against the manifest written next to the checkpoint
    being restored, warning (not raising — resuming under a deliberately
    changed scenario is legal, just worth flagging) on every mismatching
    scenario field.  Returns the mismatch list, or None when no manifest
    exists."""
    path = manifest_path(restore_path)
    if not os.path.exists(path):
        return None
    try:
        saved = load_manifest(path)["spec_parsed"]
    except (ValueError, KeyError, TypeError, OSError) as e:
        warnings.warn(f"unreadable spec manifest {path}: {e}")
        return None
    mismatches = diff_specs(saved, spec)
    if mismatches:
        warnings.warn(
            f"restoring {restore_path} under a spec that differs from its "
            f"manifest on: {', '.join(mismatches)} (saved manifest: {path})")
    return mismatches
