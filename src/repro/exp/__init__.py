"""repro.exp — the declarative experiment front door.

One :class:`ExperimentSpec` (a frozen dataclass tree: topology + channel +
algorithm + data + model + run) describes any scenario the repo can run;
``build(spec)`` lowers it to the realized schedule / update rule / data
stream for both runtimes, and ``run(spec)`` is the single entry point the
CLI (``launch/train.py``), the examples, and the benchmark sweeps all call.
Specs serialize to strict JSON (``to_dict``/``from_dict``: unknown keys
error, defaults elided) and hash stably (``spec_hash``) for BENCH rows and
reproducibility manifests; ``sweep`` grid-expands a base spec over
dotted-path override lists.
"""

from .build import Built, Result, build, run, weights_per_step  # noqa: F401
from .manifest import (  # noqa: F401
    check_restore_spec,
    diff_specs,
    load_manifest,
    manifest_path,
    resolved_manifest,
    write_manifest,
)
from .registry import (  # noqa: F401
    ALGORITHMS,
    CHANNELS,
    COMPRESSIONS,
    GOSSIP_IMPLS,
    LOCAL_OPTS,
    MOBILITY_TOPOLOGIES,
    MODEL_KINDS,
    ROUTING_POLICIES,
    SERVE_DTYPES,
    TOPOLOGIES,
    build_channel_models,
    build_compression,
    build_local_opt,
    build_topology,
    make_weight_schedule,
    register_topology,
)
from .registry import (  # noqa: F401
    OBS_BOUNDS,
    OBS_METRICS,
    SINKS,
    build_sink,
    channel_label,
    resolve_obs_names,
)
from .spec import (  # noqa: F401
    AlgorithmSpec,
    ChannelSpec,
    CompressionSpec,
    DataSpec,
    ExperimentSpec,
    ModelRef,
    ObsSpec,
    RunSpec,
    ServeSpec,
    TopologySpec,
    from_dict,
    from_json,
    load,
    spec_hash,
    sweep,
    to_dict,
    to_json,
    with_field,
    with_overrides,
)
