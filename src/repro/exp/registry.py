"""String-keyed registries for every scenario vocabulary.

The single source of truth for which topologies / channel models / update
rules / local optimizers / gossip implementations exist: the CLI derives
its ``choices`` lists from here, the builder resolves spec fields through
here, and error messages enumerate from here — adding a registry entry
updates all of them at once.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from .. import optim
from ..core import compress, engine, gossip, topology as topo
from ..obs import metrics as obs_metrics, optimality as obs_optimality
from ..sim import channel as sim_channel, faults as sim_faults, \
    mobility as sim_mobility
from .spec import ChannelSpec, TopologySpec

# ---------------------------------------------------------------------------
# Topologies: name -> builder(spec, n, *, horizon, seed) -> WeightSchedule
# ---------------------------------------------------------------------------

TOPOLOGIES: Dict[str, Callable] = {}


def register_topology(name: str):
    """Register a topology builder under ``name`` (it becomes a legal
    ``TopologySpec.kind``, a CLI ``--topology`` choice, and a sweep axis)."""
    def deco(fn):
        TOPOLOGIES[name] = fn
        return fn
    return deco


@register_topology("sun")
def _sun(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.theorem3_weight_schedule(n, s.beta)


@register_topology("ring")
def _ring(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(topo.StaticSchedule(topo.ring_graph(n)))


@register_topology("one-peer-exp")
def _one_peer_exp(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(topo.one_peer_exponential_schedule(n))


@register_topology("static-exp")
def _static_exp(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        topo.StaticSchedule(topo.static_exponential_graph(n)))


@register_topology("federated")
def _federated(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        topo.federated_schedule(n, s.local_steps))


@register_topology("complete")
def _complete(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.WeightSchedule((np.ones((n, n)) / n,),
                                 (topo.RoundStructure("complete"),))


@register_topology("random-matching")
def _random_matching(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(topo.random_matching_schedule(n))


@register_topology("resampled-matching")
def _resampled_matching(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        topo.resampled_matching_schedule(n, seed=seed), horizon=horizon)


@register_topology("erdos-renyi")
def _erdos_renyi(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        topo.erdos_renyi_schedule(n, s.er_p, seed=seed))


@register_topology("geometric-mobility")
def _geometric_mobility(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        sim_mobility.random_geometric_schedule(n, s.radius, seed=seed),
        horizon=horizon)


@register_topology("waypoint-mobility")
def _waypoint_mobility(s: TopologySpec, n: int, *, horizon=None, seed=0):
    return gossip.schedule_from_topology(
        sim_mobility.random_waypoint_schedule(n, s.radius, seed=seed),
        horizon=horizon)


@register_topology("random-sun")
def _random_sun(s: TopologySpec, n: int, *, horizon=None, seed=0):
    """The §6 Figure 2 protocol: sun-shaped graphs whose |C| = ``centers``
    center set is re-drawn randomly for each of ``resample_period`` rounds,
    with the I - L/d_max Laplacian weights the paper's experiments use."""
    rng = np.random.default_rng(seed)
    mats, structs = [], []
    for _ in range(s.resample_period):
        center = rng.choice(n, size=s.centers, replace=False)
        adj = topo.sun_shaped_graph(n, center)
        mats.append(gossip.laplacian_rule(adj))
        structs.append(topo.classify_adjacency(adj))
    return gossip.WeightSchedule(tuple(mats), tuple(structs))


@register_topology("hierarchical")
def _hierarchical(s: TopologySpec, n: int, *, horizon=None, seed=0):
    """Two-level pod schedule (the Bagua-style hierarchical pattern):
    ``local_steps`` rounds of intra-pod averaging (W = I_m ⊗ J_p, one
    allreduce per pod) followed by one inter-pod round where pods pair up
    round-robin (W = B ⊗ J_p with B = ½I + ½P a matching over pod means).
    Every round factors across pod boundaries, so with ``pods`` threaded
    to the planner the whole plan lowers to ``two_level`` — dense
    intra-pod psum composed with the matching inter-pod peer exchange.

    ``pods`` is the pod size p (must divide n, pod-major node order);
    with fewer than two pods the inter-pod round degenerates to the
    global average."""
    p = s.pods
    if p < 1 or n % p:
        raise ValueError(f"hierarchical topology needs pods | nodes, got "
                         f"pods={p}, nodes={n}")
    m = n // p
    Jp = np.ones((p, p)) / p
    intra = np.kron(np.eye(m), Jp)
    mats, structs = [], []
    if m > 1 and not (m & (m - 1)):
        # hypercube matchings over pods: log2(m) distinct pairings/period
        pod_sched = topo.one_peer_exponential_schedule(m)
        inters = [0.5 * np.eye(m) + 0.5 * pod_sched(t).astype(float)
                  * ~np.eye(m, dtype=bool) for t in range(pod_sched.period)]
    else:
        # non-power-of-two pod count: one global pod average per period
        inters = [np.ones((m, m)) / m]
    for B in inters:
        for _ in range(max(0, s.local_steps)):
            mats.append(intra)
            structs.append(topo.classify_adjacency(intra > 0))
        mats.append(np.kron(B, Jp))
        structs.append(topo.classify_adjacency(mats[-1] > 0))
    return gossip.WeightSchedule(tuple(mats), tuple(structs))


@register_topology("random-sampled")
def _random_sampled(s: TopologySpec, n: int, *, horizon=None, seed=0):
    """Client sampling at scale: each round draws ``sample_k`` of the ``n``
    nodes, places them by hashed waypoint mobility, and gossips over the
    unit-disk graph among the sampled cohort with Metropolis weights.  The
    schedule is an edge-list :class:`repro.sparse.SparseWeightSchedule`
    (never a dense matrix), so ``n`` can reach 10^5..10^6 — per-round cost
    is O(sample_k^2) to realize and O(edges) to mix."""
    from .. import sparse
    if horizon is None:
        raise ValueError("random-sampled topology needs a horizon")
    return sparse.sampled_weight_schedule(n, s.sample_k, radius=s.radius,
                                          seed=seed, horizon=horizon)


MOBILITY_TOPOLOGIES = ("geometric-mobility", "waypoint-mobility")

# Families whose builder returns an edge-list SparseWeightSchedule
# (is_sparse = True): faults realize via repro.sparse.realize_sparse_schedule
# and telemetry via SparseTelemetryRecorder, never densifying.
SPARSE_TOPOLOGIES = ("random-sampled",)


def build_topology(s: TopologySpec, n: int, *, horizon: int | None = None,
                   seed: int = 0) -> gossip.WeightSchedule:
    """Realize a :class:`TopologySpec` into a ``WeightSchedule`` for ``n``
    nodes.  ``horizon`` is required by the non-periodic families
    (resampled-matching, the mobility models); ``seed`` streams every
    randomized family."""
    if s.kind not in TOPOLOGIES:
        raise ValueError(f"unknown topology {s.kind!r} "
                         f"(have {sorted(TOPOLOGIES)})")
    return TOPOLOGIES[s.kind](s, n, horizon=horizon, seed=seed)


def make_weight_schedule(kind: str, n: int, beta: float, *,
                         horizon: int | None = None, seed: int = 0,
                         er_p: float = 0.5,
                         radius: float = 0.45) -> gossip.WeightSchedule:
    """Legacy positional entry (the pre-spec ``launch.train`` helper) —
    kept for benchmarks/tests; new code should build a
    :class:`TopologySpec` and call :func:`build_topology`."""
    return build_topology(
        TopologySpec(kind=kind, beta=beta, er_p=er_p, radius=radius),
        n, horizon=horizon, seed=seed)


# ---------------------------------------------------------------------------
# Channel / fault models: ChannelSpec field -> factory(rate, seed)
# ---------------------------------------------------------------------------

# Per-stream seed offsets keep one --seed moving every stream together
# without correlating them (same constants as the historical CLI).
CHANNELS: Dict[str, Callable] = {
    "link_drop": lambda p, seed: sim_channel.BernoulliDropChannel(
        p, seed=seed + 101),
    "burst_loss": lambda p, seed: sim_channel.GilbertElliottChannel(
        p, seed=seed + 202),
    "churn": lambda p, seed: sim_faults.NodeChurn(p, seed=seed + 303),
    "straggler": lambda p, seed: sim_faults.StragglerInjection(
        p, seed=seed + 404),
}


def build_channel_models(s: ChannelSpec, seed: int = 0) -> list:
    """Fault-model instances for every non-zero rate in ``s`` (empty list =
    ideal channel), in deterministic field order."""
    return [CHANNELS[name](rate, seed)
            for name in ("link_drop", "burst_loss", "churn", "straggler")
            if (rate := getattr(s, name)) > 0]


# ---------------------------------------------------------------------------
# Algorithms, local optimizers, gossip implementations
# ---------------------------------------------------------------------------

ALGORITHMS = engine.ALGORITHMS  # the engine's rule registry IS the registry

LOCAL_OPTS: Dict[str, Callable | None] = {
    "sgd": None,  # the paper-pure update: no transform
    "momentum": optim.momentum,
    "adam": optim.adam,
}

GOSSIP_IMPLS = ("dense", "pallas", "auto")

MODEL_KINDS = ("arch", "logreg")

# ---------------------------------------------------------------------------
# Serving (repro.serve): request routing policies and cache/param dtypes
# ---------------------------------------------------------------------------

# user id -> fleet node.  'user-affinity' pins each user to one node's
# personalization (stable hash); 'round-robin' cycles the fleet (the
# uniform-fleet ablation — every model is interchangeable).
ROUTING_POLICIES = ("user-affinity", "round-robin")

SERVE_DTYPES = ("bf16", "f32")

# Gossip payload compression schemes (core.compress owns the vocabulary).
COMPRESSIONS = compress.SCHEMES


def build_compression(s) -> compress.CompressionConfig | None:
    """Lower a :class:`repro.exp.spec.CompressionSpec` to the runtime
    :class:`repro.core.compress.CompressionConfig` (None when scheme is
    'none' — every runtime treats that as the uncompressed fast path)."""
    if s.scheme not in COMPRESSIONS:
        raise ValueError(f"unknown compression scheme {s.scheme!r} "
                         f"(have {sorted(COMPRESSIONS)})")
    if s.scheme == "none":
        return None
    return compress.CompressionConfig(scheme=s.scheme,
                                      error_feedback=s.error_feedback,
                                      warmup=s.warmup, group=s.group)


def build_local_opt(name: str):
    """Instantiate a local-optimizer transform (None for plain sgd)."""
    if name not in LOCAL_OPTS:
        raise ValueError(f"unknown local_opt {name!r} "
                         f"(have {sorted(LOCAL_OPTS)})")
    factory = LOCAL_OPTS[name]
    return factory() if factory is not None else None


# ---------------------------------------------------------------------------
# Observability: metric names, sink backends, lower bounds
# ---------------------------------------------------------------------------

# The legal ``ObsSpec.names`` entries ARE the engine's in-jit metric
# vocabulary (described host-side in repro.obs.metrics.OBS_METRICS).
OBS_METRICS = obs_metrics.OBS_METRICS

SINKS: Dict[str, Callable] = {
    "jsonl": lambda path: obs_metrics.EventLog(path),
    "memory": lambda path: obs_metrics.MemorySink(),
}

OBS_BOUNDS = obs_optimality.BOUNDS  # ObsSpec.bound vocabulary


def build_sink(obs_spec) -> "obs_metrics.MetricsSink":
    """Instantiate the event sink an :class:`repro.exp.spec.ObsSpec`
    selects (``jsonl`` needs ``obs_spec.metrics`` as the path; ``memory``
    ignores it)."""
    if obs_spec.sink not in SINKS:
        raise ValueError(f"unknown obs sink {obs_spec.sink!r} "
                         f"(have {sorted(SINKS)})")
    if obs_spec.sink == "jsonl" and not obs_spec.metrics:
        raise ValueError("obs.sink='jsonl' requires obs.metrics "
                         "(the event-log path)")
    return SINKS[obs_spec.sink](obs_spec.metrics)


def resolve_obs_names(names, rule=None) -> tuple:
    """Normalize ``ObsSpec.names`` to the engine-ready metric tuple
    (see :func:`repro.obs.metrics.resolve_names`)."""
    return obs_metrics.resolve_names(names, rule)


def channel_label(s: ChannelSpec) -> str:
    """Short label of the active degradations ("ideal" for none) — the
    channel leg of the optimality-gap cell key."""
    active = [name for name in ("link_drop", "burst_loss", "churn",
                                "straggler") if getattr(s, name) > 0]
    return "+".join(active) if active else "ideal"
