"""Lowering: realize an :class:`ExperimentSpec` into runnable pieces, and
``run(spec)`` — the one entry point every runtime shares.

``build(spec)`` resolves every string-keyed field through
:mod:`repro.exp.registry` and materializes the realized scenario — the
(post-fault) :class:`~repro.core.gossip.WeightSchedule`, the
:class:`~repro.core.engine.UpdateRule`, the gossip plan, the telemetry
recorder, and the model/data pieces of whichever runtime the spec's
``model.kind`` selects:

* ``arch``   — the distributed runtime: a registered architecture trained
  via :func:`repro.dist.steps.make_train_step` + the unified
  :mod:`repro.core.driver` staging/loop (what ``launch/train.py`` runs);
* ``logreg`` — the host reference runtime: the paper's §6 non-convex
  logistic regression driven by :func:`repro.core.driver.run_algorithm`
  (what the examples and paper-claims benchmarks run).

``run(spec)`` builds, writes the reproducibility manifest next to every
declared output, runs, and returns a :class:`Result`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import configs
from ..checkpoint import load_checkpoint, save_checkpoint
from ..core import algorithms as alg, driver, engine, gossip
from ..data import (logreg_dataset, logreg_dataset_dirichlet,
                    logreg_loss_and_grad, token_stream_for)
from ..obs import console as obs_console, metrics as obs_metrics, \
    optimality as obs_optimality, trace as obs_trace
from ..sim import faults as sim_faults, telemetry as sim_telemetry
from . import manifest as mf, registry
from .spec import ExperimentSpec


class Result(NamedTuple):
    """What ``run(spec)`` returns.  ``history`` is the runtime's record
    list (dicts with loss/consensus for ``arch``; ``(T, eval)`` pairs for
    ``logreg``); ``telemetry`` is the mixing-telemetry recorder when the
    scenario warranted one (faults, mobility, or ``run.telemetry`` set);
    ``built`` is the realized scenario (:class:`Built`) — consumers that
    need the realized schedule/plan read it here instead of re-building;
    ``serve`` is the :class:`repro.serve.ServeResult` of the post-training
    serve phase when ``spec.serve`` enables one, else None."""

    state: Any
    history: list
    telemetry: Optional[sim_telemetry.TelemetryRecorder]
    spec: ExperimentSpec
    built: "Built" = None
    serve: Any = None


@dataclasses.dataclass
class Built:
    """Everything ``build(spec)`` realized.  Scenario pieces (rule,
    schedule, plan, faults, telemetry) are populated for every model kind;
    ``cfg``/``model``/``stream`` only for ``arch``;
    ``grad_fn``/``eval_fn``/``x0`` only for ``logreg``."""

    spec: ExperimentSpec
    rule: engine.UpdateRule
    wps: int
    horizon: int
    schedule: Any                 # realized WeightSchedule (post-fault)
    plan: Any                     # GossipPlan | None (gossip_impl == auto)
    fault_models: list
    local_opt: Any
    telemetry: Optional[sim_telemetry.TelemetryRecorder]
    cfg: Any = None
    model: Any = None
    stream: Any = None
    grad_fn: Any = None
    eval_fn: Any = None
    x0: Any = None
    obs: Optional[obs_metrics.ObsRecorder] = None
    obs_names: tuple = ()
    tracer: Optional[obs_trace.Tracer] = None
    state_dim: Optional[int] = None   # per-node state entries (when known)

    @property
    def realized(self) -> dict:
        """The manifest's ``realized`` section: quantities a reader cannot
        derive from the spec alone."""
        out = {
            "period": int(self.schedule.period),
            "weights_per_step": int(self.wps),
            "horizon": int(self.horizon),
            "seed": int(self.spec.run.seed),
            "plan_kinds": (None if self.plan is None
                           else sorted(set(self.plan.kinds))),
        }
        c = self.spec.compression
        comp = {"scheme": c.scheme, "state_dim": self.state_dim}
        if c.enabled:
            comp.update(error_feedback=c.error_feedback, warmup=c.warmup,
                        group=c.group)
        if self.state_dim is not None:
            from ..core import compress
            comp["bytes_per_round"] = compress.payload_bytes(
                self.state_dim, c.scheme, c.group)
            comp["baseline_bytes_per_round"] = compress.payload_bytes(
                self.state_dim, "none")
        out["compression"] = comp
        if getattr(self.schedule, "is_sparse", False):
            e = self.schedule.edges_per_round
            snd = self.schedule.senders_per_round
            out["edges_per_round"] = {
                "min": int(e.min()), "max": int(e.max()),
                "mean": round(float(e.mean()), 1)}
            out["senders_per_round"] = {
                "min": int(snd.min()), "max": int(snd.max()),
                "mean": round(float(snd.mean()), 1)}
        if self.spec.obs.metrics:
            out["event_log"] = self.spec.obs.metrics
            out["obs_names"] = list(self.obs_names)
        sv = self.spec.serve
        if sv.enabled:
            out["serve"] = {"requests": sv.requests,
                            "fleet": sv.fleet or self.spec.run.nodes,
                            "batch": sv.batch, "routing": sv.routing}
        return out


def weights_per_step(algorithm) -> int:
    """Gossip rounds one step of this :class:`AlgorithmSpec` consumes (the
    paper's budget accounting) — derived from the engine rule, the single
    source of truth, so ``steps = T // weights_per_step(a)`` stays correct
    if a rule's round structure ever changes."""
    R = algorithm.R if algorithm.name == "mc_dsgt" else 1
    return engine.make_rule(algorithm.name, gamma=algorithm.gamma,
                            R=R).weights_per_step


def _validate(spec: ExperimentSpec) -> None:
    """Registry-driven validation: every string-keyed field must name a
    registered entry, and the error enumerates the legal values."""
    t, a, r, m = spec.topology, spec.algorithm, spec.run, spec.model
    if t.kind not in registry.TOPOLOGIES:
        raise ValueError(f"topology.kind={t.kind!r}: unknown "
                         f"(have {sorted(registry.TOPOLOGIES)})")
    if a.name not in registry.ALGORITHMS:
        raise ValueError(f"algorithm.name={a.name!r}: unknown "
                         f"(have {sorted(registry.ALGORITHMS)})")
    if a.local_opt not in registry.LOCAL_OPTS:
        raise ValueError(f"algorithm.local_opt={a.local_opt!r}: unknown "
                         f"(have {sorted(registry.LOCAL_OPTS)})")
    if r.gossip_impl not in registry.GOSSIP_IMPLS:
        raise ValueError(f"run.gossip_impl={r.gossip_impl!r}: unknown "
                         f"(have {sorted(registry.GOSSIP_IMPLS)})")
    if m.kind not in registry.MODEL_KINDS:
        raise ValueError(f"model.kind={m.kind!r}: unknown "
                         f"(have {sorted(registry.MODEL_KINDS)})")
    if a.delay < 0:
        raise ValueError(f"algorithm.delay={a.delay}: must be >= 0")
    if a.comm_interval < 1:
        raise ValueError(f"algorithm.comm_interval={a.comm_interval}: "
                         "must be >= 1")
    if t.pods < 1:
        raise ValueError(f"topology.pods={t.pods}: must be >= 1")
    if t.pods > 1 and r.nodes % t.pods:
        raise ValueError(f"topology.pods={t.pods} must divide "
                         f"run.nodes={r.nodes}")
    if t.kind in registry.SPARSE_TOPOLOGIES:
        if not 2 <= t.sample_k <= r.nodes:
            raise ValueError(f"topology.sample_k={t.sample_k}: the "
                             f"{t.kind!r} family samples a per-round "
                             f"cohort and needs 2 <= sample_k <= "
                             f"run.nodes={r.nodes}")
        if m.kind != "logreg":
            raise ValueError(f"topology.kind={t.kind!r} runs the host "
                             "reference runtime: model.kind must be "
                             "'logreg'")
        if a.name == "personalized":
            raise ValueError(
                f"algorithm.name='personalized' stages per-node dense "
                f"weight rows, which the edge-form {t.kind!r} family "
                "never materializes — use a dense topology")
        from ..sparse import DENSE_GUARD
        if r.nodes > DENSE_GUARD and r.gossip_impl != "auto":
            raise ValueError(
                f"run.nodes={r.nodes} exceeds the {DENSE_GUARD}-node dense "
                "guard: the dense host path would materialize (n, n) "
                "matrices — set run.gossip_impl='auto'")
    if m.kind == "logreg":
        if r.gossip_impl == "pallas":
            raise ValueError("model.kind='logreg' runs the host runtime: "
                             "gossip_impl must be 'dense' or 'auto'")
        if r.checkpoint or r.restore:
            raise ValueError("model.kind='logreg' does not support "
                             "checkpoint/restore (use the 'arch' runtime)")
    c = spec.compression
    if c.scheme not in registry.COMPRESSIONS:
        raise ValueError(f"compression.scheme={c.scheme!r}: unknown "
                         f"(have {sorted(registry.COMPRESSIONS)})")
    if c.group < 1:
        raise ValueError(f"compression.group={c.group}: must be >= 1")
    if c.warmup < 0:
        raise ValueError(f"compression.warmup={c.warmup}: must be >= 0")
    o = spec.obs
    if o.sink not in registry.SINKS:
        raise ValueError(f"obs.sink={o.sink!r}: unknown "
                         f"(have {sorted(registry.SINKS)})")
    if o.bound not in registry.OBS_BOUNDS:
        raise ValueError(f"obs.bound={o.bound!r}: unknown "
                         f"(have {sorted(registry.OBS_BOUNDS)})")
    if o.every < 1:
        raise ValueError(f"obs.every={o.every}: must be >= 1")
    registry.resolve_obs_names(o.names)  # raises on unknown metric names
    s = spec.serve
    if s.routing not in registry.ROUTING_POLICIES:
        raise ValueError(f"serve.routing={s.routing!r}: unknown "
                         f"(have {sorted(registry.ROUTING_POLICIES)})")
    if s.dtype not in registry.SERVE_DTYPES:
        raise ValueError(f"serve.dtype={s.dtype!r}: unknown "
                         f"(have {sorted(registry.SERVE_DTYPES)})")
    if s.requests < 0:
        raise ValueError(f"serve.requests={s.requests}: must be >= 0")
    if s.enabled:
        if m.kind != "arch":
            raise ValueError("serve.requests > 0 needs the 'arch' runtime: "
                             "serving decodes a trained transformer fleet "
                             f"(model.kind={m.kind!r})")
        if s.batch < 1 or s.max_new < 1 or s.prompt_len < 1:
            raise ValueError("serve.batch/max_new/prompt_len must be >= 1 "
                             f"(got {s.batch}/{s.max_new}/{s.prompt_len})")
        if not 0 <= s.fleet <= r.nodes:
            raise ValueError(f"serve.fleet={s.fleet}: must be 0 (= all "
                             f"run.nodes) or <= run.nodes={r.nodes}")


def build(spec: ExperimentSpec) -> Built:
    """Realize ``spec``: resolve registries, materialize the (possibly
    fault-degraded) weight schedule, lower the gossip plan, and construct
    the runtime-specific model/data pieces."""
    _validate(spec)
    rs, al = spec.run, spec.algorithm
    n = rs.nodes
    # R (consensus/accumulation rounds) is mc_dsgt's knob; every other rule
    # is defined at R=1 and the engine enforces it
    R = al.R if al.name == "mc_dsgt" else 1
    comp = registry.build_compression(spec.compression)
    rule = engine.make_rule(al.name, gamma=al.gamma, R=R, compression=comp,
                            delay=al.delay, comm_interval=al.comm_interval,
                            tau=al.tau)
    wps = rule.weights_per_step

    # horizon only matters for the non-periodic schedules (resampled
    # matching, mobility) and realized fault windows; the x4 cushion covers
    # --restore continuations (wrap past it is benign)
    horizon = (rs.steps + 1) * wps * 4
    sched = registry.build_topology(spec.topology, n, horizon=horizon,
                                    seed=rs.seed)
    fault_models = registry.build_channel_models(spec.channel, rs.seed)
    is_sparse = getattr(sched, "is_sparse", False)
    if fault_models:
        # ideal plan -> channel degradation -> repair -> (re-)lowering: the
        # realized window replaces the schedule wholesale, so both gossip
        # impls consume the same post-fault matrices.  Sparse schedules are
        # degraded edge-list-wise (per-edge hash streams, never densified).
        if is_sparse:
            from .. import sparse
            sched = sparse.realize_sparse_schedule(sched, fault_models)
        else:
            sched = sim_faults.realize_weight_schedule(sched, fault_models,
                                                       rounds=horizon)
    pods = spec.topology.pods if spec.topology.pods > 1 else None
    plan = (sched.plan(0, sched.period, pods=pods,
                       personalized=rule.personalized)
            if rs.gossip_impl == "auto" else None)
    telem = None
    if fault_models or rs.telemetry or comp is not None or rule.delay or \
            is_sparse or spec.topology.kind in registry.MOBILITY_TOPOLOGIES:
        if is_sparse:
            from ..sparse import SparseTelemetryRecorder as _Recorder
        else:
            _Recorder = sim_telemetry.TelemetryRecorder
        telem = _Recorder(sched, wps=wps, every=rs.log_every,
                          compression=comp, delay=rule.delay)
    built = Built(spec=spec, rule=rule, wps=wps, horizon=horizon,
                  schedule=sched, plan=plan, fault_models=fault_models,
                  local_opt=registry.build_local_opt(al.local_opt),
                  telemetry=telem)
    if spec.obs.enabled:
        _build_obs(built)

    if spec.model.kind == "arch":
        from ..models import build as build_model
        cfg = configs.get(spec.model.arch)
        if spec.model.preset == "reduced":
            cfg = cfg.reduced()
        built.cfg = cfg
        built.model = build_model(cfg)
        built.stream = token_stream_for(
            cfg, n, R, spec.data.batch, spec.data.seq, seed=rs.seed,
            active_vocab=spec.data.active_vocab,
            hetero_alpha=spec.data.hetero_alpha)
        try:  # abstract eval only — no weight materialization
            shapes = jax.eval_shape(
                lambda key: built.model.init(key, jnp.float32),
                jax.random.key(0))
            built.state_dim = sum(int(l.size)
                                  for l in jax.tree.leaves(shapes))
        except Exception:
            built.state_dim = None
    else:
        mr = spec.model
        if spec.data.hetero_alpha is not None:
            H, y = logreg_dataset_dirichlet(n, mr.m, mr.d,
                                            alpha=spec.data.hetero_alpha,
                                            seed=rs.seed)
        else:
            H, y = logreg_dataset(n, mr.m, mr.d, seed=rs.seed)
        _, _, stoch, _, gnorm2 = logreg_loss_and_grad(rho=mr.rho)
        batch = spec.data.batch
        built.grad_fn = lambda xs, key: stoch(xs, H, y, key, batch)
        built.eval_fn = lambda xb: gnorm2(xb, H, y)
        built.x0 = jnp.zeros((n, mr.d))
        built.state_dim = mr.d
    return built


def _effective_beta(sched, period: int, cap: int = 64) -> float:
    """Measured per-round mixing parameter of the realized schedule: the
    window contraction over (up to ``cap`` rounds of) one period, taken to
    the per-round geometric mean — what the lower-bound floor's network
    term should be evaluated at."""
    rounds = max(1, min(int(period), cap))
    if getattr(sched, "is_sparse", False):
        # edge-list schedules never densify: the window contraction comes
        # from power iteration on the participant subspace
        from .. import sparse
        c = 1.0 - sparse.sparse_windowed_gap(
            [sched.round(t) for t in range(rounds)])
    else:
        c = gossip.consensus_contraction(sched, rounds)
    c = min(max(float(c), 0.0), 1.0 - 1e-9)
    return c ** (1.0 / rounds)


def _build_obs(built: Built) -> None:
    """Attach the repro.obs bundle to a Built: the event sink, the phase
    tracer, the optimality-gap tracker for this spec's cell, the optional
    profiler, and the :class:`~repro.obs.metrics.ObsRecorder` tying them
    together (chaining the existing TelemetryRecorder when the scenario
    has one, instead of replacing it)."""
    spec = built.spec
    rs, al, o = spec.run, spec.algorithm, spec.obs
    built.obs_names = registry.resolve_obs_names(o.names, built.rule)
    built.tracer = obs_trace.Tracer(annotate=bool(o.profile_dir))
    cell = obs_optimality.cell_key(al.name, spec.topology.kind,
                                   registry.channel_label(spec.channel))
    gap = obs_optimality.GapTracker(
        cell=cell, n=rs.nodes,
        beta=_effective_beta(built.schedule, built.schedule.period),
        bound=o.bound)
    profiler = (obs_trace.Profiler(o.profile_dir, o.profile_steps)
                if o.profile_dir else None)
    from .spec import spec_hash
    meta = {"name": f"{al.name} on {spec.topology.kind}",
            "spec_hash": spec_hash(spec), "cell": cell,
            "algo": al.name, "topology": spec.topology.kind,
            "channel": registry.channel_label(spec.channel),
            "model": spec.model.kind, "n": rs.nodes, "steps": rs.steps,
            "weights_per_step": built.wps,
            "gossip_impl": rs.gossip_impl, "every": o.every,
            "obs_names": list(built.obs_names)}
    # profile-only runs (profile_dir set, no metrics path) still need a
    # sink for the recorder's meta/summary events — an in-memory one
    sink = (obs_metrics.MemorySink() if o.sink == "jsonl" and not o.metrics
            else registry.build_sink(o))
    built.obs = obs_metrics.ObsRecorder(
        sink, every=o.every, telemetry=built.telemetry,
        tracer=built.tracer, gap=gap, profiler=profiler, meta=meta)


# ---------------------------------------------------------------------------
# run(spec): the one entry
# ---------------------------------------------------------------------------

def run(spec: ExperimentSpec, *, quiet: bool = False) -> Result:
    """Build and execute ``spec`` end to end on its runtime, writing the
    reproducibility manifest next to every declared output (checkpoint,
    telemetry, event log).  The telemetry/event-log manifests are written
    up front; the checkpoint manifest is written only AFTER the restore
    check, so resuming in place (checkpoint == restore) still compares
    against the ORIGINAL run's manifest before overwriting it."""
    built = build(spec)
    if spec.run.telemetry:
        mf.write_manifest(spec.run.telemetry, spec, realized=built.realized)
    if spec.obs.metrics:
        mf.write_manifest(spec.obs.metrics, spec, realized=built.realized)
    if built.obs is not None and built.obs.profiler is not None:
        built.obs.profiler.start()
    try:
        if spec.model.kind == "arch":
            res = _run_arch(built, quiet=quiet)
        else:
            res = _run_logreg(built)
        if spec.serve.enabled:
            # serve phase runs inside the try so its per-request obs
            # events land before the sink closes
            res = res._replace(serve=_run_serve(built, res.state,
                                                quiet=quiet))
        return res
    finally:
        if built.obs is not None:
            built.obs.close()


def _run_logreg(built: Built) -> Result:
    """Host reference runtime: the engine rule bound to the stacked-einsum
    (or planned) mixer, driven by :func:`repro.core.driver.run_algorithm`."""
    spec, rs = built.spec, built.spec.run
    algo = alg.from_rule(built.rule, built.local_opt)
    state, history = driver.run_algorithm(
        algo, built.x0, built.grad_fn, built.schedule, rs.steps,
        jax.random.key(rs.seed), eval_fn=built.eval_fn,
        eval_every=rs.eval_every, gossip_impl=rs.gossip_impl,
        plan=built.plan,
        telemetry=(built.obs if built.obs is not None else built.telemetry),
        obs=built.obs_names, tracer=built.tracer)
    if rs.telemetry and built.telemetry is not None:
        built.telemetry.dump(rs.telemetry)
    return Result(state=state, history=history, telemetry=built.telemetry,
                  spec=spec, built=built)


def _run_arch(built: Built, *, quiet: bool = False) -> Result:
    """Distributed runtime: the engine rule bound to the mesh/plan mixers
    via :func:`repro.dist.steps.make_train_step`, with the unified
    stage/bind/loop driver, checkpointing and loss/consensus logging."""
    from ..dist import steps as dsteps

    spec, rs = built.spec, built.spec.run
    stream, telem = built.stream, built.telemetry
    con = obs_console.Console(quiet=quiet)
    init_state, warm_start, train_step = dsteps.make_train_step(
        built.model, built.cfg, algo=spec.algorithm.name,
        gamma=spec.algorithm.gamma, R=built.rule.R,
        gossip_impl=rs.gossip_impl, plan=built.plan,
        local_opt=built.local_opt,
        compression=built.rule.compression,
        delay=built.rule.delay, comm_interval=built.rule.comm_interval,
        obs=built.obs_names)

    state = init_state(jax.random.key(rs.seed), rs.nodes, jnp.float32)
    state, start_step = driver.restore_or_warm(
        state, restore=rs.restore, load_fn=load_checkpoint,
        warm=lambda s: warm_start(s, stream.batch_at(0)), spec=spec)
    if rs.restore:
        con.print(f"restored step {start_step} from {rs.restore}")
    if rs.checkpoint:
        # written after the restore check (resume-in-place must be compared
        # against the original manifest first) but before the loop, so even
        # interrupted runs stay attributable
        mf.write_manifest(rs.checkpoint, built.spec, realized=built.realized)

    # Stage the whole period's gossip tensors on device ONCE; the jitted
    # step indexes them by (t mod period) — no per-step stacked()/transfer.
    staged = driver.stage(
        built.schedule, wps=built.wps,
        impl=("auto" if rs.gossip_impl == "auto" else "dense"),
        plan=built.plan,
        static_t=(rs.gossip_impl == "auto"
                  and train_step.gossip_dispatch == "static"))
    if rs.gossip_impl == "auto":
        step_fn = driver.bind_step(staged, train_step)
    else:
        step_fn = driver.bind_step(
            staged, lambda state, batch, W, t: train_step(state, batch, W))

    def record(k, t, state, out, dt):
        if built.obs is not None:
            tl = built.obs.record(k, t, state, out, dt)
        else:
            tl = (telem.record(k, t, state, out, dt)
                  if telem is not None else None)
        if k % rs.log_every != 0:
            return None
        loss = float(out["loss"])
        ce = (tl["consensus"] if tl is not None
              else sim_telemetry.consensus_distance(state.x))
        extra = ""
        if tl is not None:
            ed = tl["eff_diameter"]
            gap = tl["spectral_gap"]
            extra = (f"  gap {gap if gap is not None else float('nan'):.3f}"
                     f"  eff_diam {ed if ed is not None else '-'}")
        con.print(f"step {k:5d}  T={t:6d}  loss {loss:.4f}  "
                  f"consensus {ce:.3e}{extra}  {dt:.2f}s")
        return {"step": k, "loss": loss, "consensus": ce,
                "sec": round(dt, 3)}

    state, history = driver.run_loop(
        step_fn, state, steps=rs.steps, wps=built.wps, period=staged.period,
        start_step=start_step, extra_fn=lambda k: stream.batch_at(k + 1),
        record=record, checkpoint=rs.checkpoint, save_fn=save_checkpoint,
        tracer=built.tracer)
    if rs.checkpoint:
        con.event("saved", path=rs.checkpoint)
    if rs.telemetry and telem is not None:
        telem.dump(rs.telemetry)
        con.event("wrote_telemetry", path=rs.telemetry)
    return Result(state=state, history=history, telemetry=telem, spec=spec,
                  built=built)


def _run_serve(built: Built, state: Any, *, quiet: bool = False):
    """Post-training serve phase: slice the first ``serve.fleet`` node
    copies out of the trained stacked state and serve them with continuous
    batching (:func:`repro.serve.serve_fleet`), emitting per-request obs
    events through the run's recorder."""
    from ..serve import serve_fleet

    sv = built.spec.serve
    F = sv.fleet or built.spec.run.nodes
    fleet = jax.tree.map(lambda l: l[:F], state.x)
    res = serve_fleet(built.model, fleet, sv, obs=built.obs)
    con = obs_console.Console(quiet=quiet)
    tp = res.throughput
    con.print(f"served {tp['requests']} requests over fleet {res.fleet}  "
              f"decode {tp['decode_tok_s']:.0f} tok/s  "
              f"p50 {tp['latency_p50_ms']:.1f}ms  "
              f"p95 {tp['latency_p95_ms']:.1f}ms")
    return res
