"""CI spec-smoke entry: prove every example's spec literal builds and runs,
and that every checked-in manifest still parses (schema drift fails fast).

    PYTHONPATH=src python -m repro.exp.validate [--examples DIR]
        [--manifests GLOB] [--steps N]

Four passes:

1. every ``SPECS`` entry exported by the example scripts is rebuilt with a
   tiny run shape (``--steps``, no checkpoint/telemetry/obs I/O) and
   executed end to end through :func:`repro.exp.run`;
2. the observability path (:mod:`repro.obs`) is smoked: a tiny
   ObsSpec-enabled run must produce a parseable JSONL event log covering
   every step, a manifest that round-trips, and a report.py render;
3. the compressed-gossip axis is smoked: {sign, int8} x {20% link drop,
   federated} MC-DSGT cells run end to end and must report bytes telemetry
   and a realized bytes/round priced at the scheme's wire format;
4. every manifest matching ``--manifests`` (the checked-in scenario
   manifests under ``experiments/manifests/`` by default) is round-tripped
   through the strict ``from_dict``/``to_dict`` pair, and the run fails if
   fewer than ``--min-manifests`` matched (a vacuous glob is a failure,
   not a pass).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import importlib.util
import os
import sys

from . import manifest as mf, spec as S
from .build import run as _run


def iter_example_specs(examples_dir: str):
    """Yield ``(example_name, spec_name, spec)`` for every module-level
    ``SPECS`` mapping in ``<examples_dir>/*.py``."""
    for path in sorted(glob.glob(os.path.join(examples_dir, "*.py"))):
        name = os.path.splitext(os.path.basename(path))[0]
        modname = f"_exp_validate_{name}"
        spec_obj = importlib.util.spec_from_file_location(modname, path)
        mod = importlib.util.module_from_spec(spec_obj)
        sys.modules[modname] = mod
        spec_obj.loader.exec_module(mod)
        for spec_name, spec in getattr(mod, "SPECS", {}).items():
            yield name, spec_name, spec


def shrink(spec: S.ExperimentSpec, steps: int) -> S.ExperimentSpec:
    """A smoke-sized copy of ``spec``: ``steps`` steps, no output files, and
    a handful of short serve requests when the spec enables a serve phase
    (still exercising admit/prefill/decode/evict end to end)."""
    sv = spec.serve
    if sv.enabled:
        sv = dataclasses.replace(sv, requests=min(sv.requests, 8),
                                 batch=min(sv.batch, 4),
                                 max_new=min(sv.max_new, 4),
                                 prompt_len=min(sv.prompt_len, 8))
    return dataclasses.replace(
        spec,
        run=dataclasses.replace(
            spec.run, steps=steps, eval_every=1, checkpoint=None,
            restore=None, telemetry=None),
        obs=S.ObsSpec(), serve=sv)


def validate_obs(steps: int) -> list[str]:
    """Smoke the metrics path end to end: run a tiny ObsSpec-enabled spec,
    then assert the JSONL event log parses, covers every step, carries a
    summary, round-trips its manifest, and renders through report.py."""
    import json
    import tempfile

    from ..obs import report as obs_report
    from .build import run as _run

    failures = []
    with tempfile.TemporaryDirectory() as tmp:
        log = os.path.join(tmp, "obs.jsonl")
        spec = S.from_dict({
            "model": {"kind": "logreg", "d": 8, "m": 32},
            "algorithm": {"name": "mc_dsgt", "R": 2},
            "run": {"steps": steps + 2, "nodes": 4},
            "obs": {"metrics": log, "every": 2},
        })
        try:
            _run(spec, quiet=True)
            events = [json.loads(line) for line in open(log)]
            kinds = [e["event"] for e in events]
            n_steps = kinds.count("step")
            assert kinds[0] == "meta", f"first event {kinds[0]!r}, not meta"
            assert n_steps == spec.run.steps, \
                f"{n_steps} step events for {spec.run.steps} steps " \
                "(flush batching lost events)"
            assert kinds[-1] == "summary", "no trailing summary event"
            assert events[-1]["optimality"]["gap_ratio"] is not None
            m = mf.load_manifest(mf.manifest_path(log))
            assert m["spec_parsed"] == spec
            text = obs_report.render(events)
            assert "optimality gap" in text and "grad_norm" in text
            print(f"ok   obs:metrics-path  [{S.spec_hash(spec)}]  "
                  f"events={len(events)}")
        except Exception as e:  # noqa: BLE001 - collect, don't crash
            failures.append(f"obs:metrics-path: {type(e).__name__}: {e}")
            print(f"FAIL obs:metrics-path: {e}")
    return failures


def validate_compression(steps: int, only: str = None) -> list[str]:
    """Smoke the compressed-gossip axis end to end: {sign, int8} x {20%
    link drop, federated} MC-DSGT cells, each a 2-step ``exp.run`` that
    must produce bytes telemetry and a realized-compression manifest block
    priced at the scheme's wire format."""
    from ..core import compress

    failures = []
    scenarios = {
        "drop20": {"topology": {"kind": "waypoint-mobility", "radius": 0.45},
                   "channel": {"link_drop": 0.2}},
        "federated": {"topology": {"kind": "federated", "local_steps": 2}},
    }
    for scen, sections in scenarios.items():
        base = S.from_dict({
            "model": {"kind": "logreg", "d": 32, "m": 64},
            "algorithm": {"name": "mc_dsgt", "R": 2, "gamma": 0.2},
            "run": {"steps": steps, "nodes": 8, "eval_every": 1},
            "compression": {"group": 16},
            **sections})
        for spec in S.sweep(base, {"compression.scheme": ["sign", "int8"]}):
            tag = f"compression:{scen}-{spec.compression.scheme}"
            if only and only not in tag:
                continue
            try:
                result = _run(spec, quiet=True)
                assert result.telemetry is not None, "no telemetry recorder"
                assert result.telemetry.bytes_total > 0
                rc = result.built.realized["compression"]
                want = compress.payload_bytes(
                    spec.model.d, spec.compression.scheme,
                    spec.compression.group)
                assert rc["bytes_per_round"] == want, rc
                assert rc["bytes_per_round"] < rc["baseline_bytes_per_round"]
                print(f"ok   {tag}  [{S.spec_hash(spec)}]  "
                      f"wire_bytes={result.telemetry.bytes_total}")
            except Exception as e:  # noqa: BLE001 - collect all failures
                failures.append(f"{tag}: {type(e).__name__}: {e}")
                print(f"FAIL {tag}: {e}")
    return failures


def validate_manifests(pattern: str) -> list[str]:
    """Strict round-trip of every manifest matching ``pattern``; returns
    failure strings (empty = all good)."""
    failures = []
    for path in sorted(glob.glob(pattern)):
        try:
            m = mf.load_manifest(path)
            spec = m["spec_parsed"]
            again = S.from_dict(S.to_dict(spec))
            if again != spec:
                failures.append(f"{path}: to_dict/from_dict not a fixpoint")
            if m["spec_hash"] != S.spec_hash(spec):
                failures.append(f"{path}: stored spec_hash "
                                f"{m['spec_hash']} != {S.spec_hash(spec)}")
        except Exception as e:  # noqa: BLE001 - report, don't crash the loop
            failures.append(f"{path}: {type(e).__name__}: {e}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--examples", default="examples")
    ap.add_argument("--manifests", default="experiments/manifests/*.json")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--min-manifests", type=int, default=1,
                    help="fail unless at least this many checked-in "
                         "manifests matched --manifests (guards against "
                         "the glob silently matching nothing)")
    ap.add_argument("--only", default=None,
                    help="run only example specs whose name contains SUBSTR")
    args = ap.parse_args(argv)

    failures = []
    n_specs = 0
    for example, spec_name, spec in iter_example_specs(args.examples):
        tag = f"{example}:{spec_name}"
        if args.only and args.only not in tag:
            continue
        n_specs += 1
        try:
            small = shrink(spec, args.steps)
            # the JSON round trip is part of the contract being smoked
            assert S.from_json(S.to_json(small)) == small
            result = _run(small, quiet=True)
            assert result.history is not None
            print(f"ok   {tag}  [{S.spec_hash(small)}]  "
                  f"history={len(result.history)}")
        except Exception as e:  # noqa: BLE001 - collect all failures
            failures.append(f"{tag}: {type(e).__name__}: {e}")
            print(f"FAIL {tag}: {e}")
    print(f"{n_specs} example spec(s) smoked")

    if not args.only:
        failures += validate_obs(args.steps)
    if not args.only or "compression" in args.only:
        failures += validate_compression(args.steps, args.only)

    mfails = validate_manifests(args.manifests)
    n_manifests = len(glob.glob(args.manifests))
    print(f"{n_manifests} manifest(s) round-tripped, {len(mfails)} failed")
    failures += mfails
    if n_manifests < args.min_manifests:
        failures.append(
            f"only {n_manifests} manifest(s) matched {args.manifests!r} "
            f"(expected >= {args.min_manifests}) — the schema-drift guard "
            "would be vacuous")

    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(f"  {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
