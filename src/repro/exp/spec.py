"""The declarative experiment spec tree — one frozen dataclass per scenario
axis, composing into :class:`ExperimentSpec`, the single description of a
run that every runtime, example, benchmark and CLI entry point consumes.

The paper's contribution is a complexity statement over *scenarios* —
algorithm x time-varying topology x channel x heterogeneity — and this
module is that grid made first-class: a spec is a value (hashable,
comparable, `dataclasses.replace`-able), serializes to strict JSON
(`to_dict`/`from_dict`: unknown keys error, defaults are elided), and
`sweep` expands a base spec plus per-field override lists into the full
scenario grid.  Realization (weight schedules, fault models, update rules,
data streams) lives in :mod:`repro.exp.build`; legal values for the
string-keyed fields live in :mod:`repro.exp.registry`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Mapping, Optional, Sequence


# ---------------------------------------------------------------------------
# The spec tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The time-varying network: which schedule family and its parameters.

    ``kind`` is a :data:`repro.exp.registry.TOPOLOGIES` key.  Family
    parameters: ``beta`` (sun: Assumption 3 spectral bound), ``er_p``
    (erdos-renyi edge probability), ``radius`` (unit-disk range of the
    mobility models), ``local_steps`` (federated: local rounds between
    averaging rounds), ``centers``/``resample_period`` (random-sun: |C| and
    the number of independent center draws materialized, the §6 Figure 2
    protocol), ``pods`` (nodes per pod, pod-major order — matching the
    ``pod|data|model`` mesh layout; when > 1, rounds that factor as
    B ⊗ J_p across pod boundaries take the hierarchical two-level lowering
    under ``gossip_impl='auto'``, and the ``hierarchical`` family builds
    such schedules: ``local_steps`` intra-pod averaging rounds then one
    inter-pod matching round), ``sample_k`` (random-sampled: clients
    gossiping per round — the sparse edge-list family, where per-round
    cost is O(edges) and ``n`` can reach 10^5..10^6)."""

    kind: str = "sun"
    beta: float = 0.75
    er_p: float = 0.5
    radius: float = 0.45
    local_steps: int = 4
    centers: int = 1
    resample_period: int = 16
    pods: int = 1
    sample_k: int = 0


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Channel/fault degradation applied to the ideal schedule (all rates
    are per-round probabilities; 0 everywhere = ideal channel).  Realized
    via :mod:`repro.sim`: mask -> repair -> re-classified lowering."""

    link_drop: float = 0.0    # iid per-link Bernoulli loss
    burst_loss: float = 0.0   # Gilbert-Elliott good->bad transition prob
    churn: float = 0.0        # per-node failure prob (all links down)
    straggler: float = 0.0    # per-node deadline-miss prob


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """Which update rule and its scalars.  ``name`` is an
    :data:`repro.exp.registry.ALGORITHMS` entry; ``R`` (consensus/
    accumulation rounds) only applies to ``mc_dsgt`` — every other rule is
    defined at R=1 and the builder normalizes; ``local_opt`` is a
    :data:`repro.exp.registry.LOCAL_OPTS` key.

    ``delay`` is the stale-window (overlapped-gossip) axis: each step's
    gossip window is applied to the payload from ``delay`` steps ago and
    only the correction is folded into the fresh payload, so the mix
    collectives carry no data dependence on the current gradient (see
    :class:`repro.core.engine.UpdateRule`); ``delay=0`` is today's
    synchronous path, bit-exact.  ``comm_interval`` mixes every k driver
    steps with pure local updates in between (identity mix on skipped
    steps; incompatible with compression)."""

    name: str = "mc_dsgt"
    gamma: float = 0.05
    R: int = 2
    local_opt: str = "sgd"
    delay: int = 0
    comm_interval: int = 1
    tau: float = 4.0   # personalized: loss-proximity similarity temperature


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Per-node data stream.  For ``arch`` models: synthetic LM token
    batches (``seq``, ``active_vocab``).  For ``logreg``: the §6 protocol
    (``batch`` = stochastic-oracle minibatch).  ``hetero_alpha`` is the
    Dirichlet(alpha) non-iid knob on both (None = the model family's
    default partition: iid tokens / the paper's 80-20 label split)."""

    batch: int = 2
    seq: int = 64
    active_vocab: int = 64
    hetero_alpha: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ModelRef:
    """What is being optimized.  ``kind='arch'``: a registered architecture
    (:mod:`repro.configs`) trained by the distributed runtime
    (:mod:`repro.dist.steps`).  ``kind='logreg'``: the paper's non-convex
    logistic regression driven by the host reference runtime
    (:func:`repro.core.driver.run_algorithm`)."""

    kind: str = "arch"
    arch: str = "qwen1.5-0.5b"
    preset: str = "reduced"
    d: int = 64        # logreg: feature dim
    m: int = 256       # logreg: samples per node
    rho: float = 0.1   # logreg: non-convex regularizer weight


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Gossip payload compression (:mod:`repro.core.compress`).  ``scheme``
    is a :data:`repro.exp.registry.COMPRESSIONS` key (``'none'`` = full
    f32, the default); ``error_feedback`` carries each round's
    quantization error into the next payload; ``warmup`` gossips at full
    precision for the first N driver steps; ``group`` is entries per
    quantization scale (one f32 scale transmitted per group)."""

    scheme: str = "none"
    error_feedback: bool = True
    warmup: int = 0
    group: int = 256

    @property
    def enabled(self) -> bool:
        return self.scheme != "none"


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Run shape and I/O: everything that is not the scenario itself."""

    steps: int = 20
    nodes: int = 4
    seed: int = 0
    gossip_impl: str = "dense"    # repro.exp.registry.GOSSIP_IMPLS
    log_every: int = 1
    eval_every: int = 1           # logreg runtime: eval_fn cadence
    checkpoint: Optional[str] = None
    restore: Optional[str] = None
    telemetry: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability (:mod:`repro.obs`): in-jit step metrics into an event
    log, phase tracing, and optimality-gap tracking.  Off by default —
    enabled when ``metrics`` (the JSONL event-log path) or ``profile_dir``
    is set.  ``names`` selects engine metrics (``'auto'`` = the update
    rule's default set, or a comma-separated subset of
    :data:`repro.obs.metrics.OBS_METRICS`); ``every`` is the host flush
    batch (device scalars cross the host boundary once per ``every``
    steps); ``sink`` is a :data:`repro.exp.registry.SINKS` key;
    ``profile_dir``/``profile_steps`` dump a jax profiler trace of the
    first N steps; ``bound`` names the lower-bound reference the gap is
    measured against (:data:`repro.obs.optimality.BOUNDS`)."""

    metrics: Optional[str] = None
    every: int = 10
    names: str = "auto"
    sink: str = "jsonl"
    bound: str = "paper"
    profile_dir: Optional[str] = None
    profile_steps: int = 8

    @property
    def enabled(self) -> bool:
        return bool(self.metrics or self.profile_dir)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Fleet serving (:mod:`repro.serve`): serve the trained per-node model
    fleet behind one continuously-batched endpoint.  Off by default —
    enabled when ``requests > 0``, in which case :func:`repro.exp.run`
    follows training with a serve phase and attaches a
    :class:`repro.serve.ServeResult` to the run result.

    ``fleet`` is the number of personalized models served (0 = the trained
    fleet, ``run.nodes``); ``batch`` caps concurrently-decoding request
    slots (the continuous-batching window); ``max_new`` / ``prompt_len``
    shape each synthetic request; ``routing`` is a
    :data:`repro.exp.registry.ROUTING_POLICIES` key mapping a user id to
    its node's personalization; ``dtype`` selects the serve-side param /
    KV-cache precision (``'bf16'`` or ``'f32'``)."""

    requests: int = 0
    batch: int = 8
    max_new: int = 16
    prompt_len: int = 16
    fleet: int = 0
    routing: str = "user-affinity"
    dtype: str = "bf16"
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return self.requests > 0


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment = one point of the scenario grid.  The default value
    of every field matches the historical ``launch/train.py`` flag default,
    so an empty spec is the CLI's zero-flag run."""

    model: ModelRef = ModelRef()
    data: DataSpec = DataSpec()
    algorithm: AlgorithmSpec = AlgorithmSpec()
    topology: TopologySpec = TopologySpec()
    channel: ChannelSpec = ChannelSpec()
    compression: CompressionSpec = CompressionSpec()
    run: RunSpec = RunSpec()
    serve: ServeSpec = ServeSpec()
    obs: ObsSpec = ObsSpec()


_SECTION_TYPES = {"model": ModelRef, "data": DataSpec,
                  "algorithm": AlgorithmSpec, "topology": TopologySpec,
                  "channel": ChannelSpec, "compression": CompressionSpec,
                  "run": RunSpec, "serve": ServeSpec, "obs": ObsSpec}


# ---------------------------------------------------------------------------
# Strict serialization
# ---------------------------------------------------------------------------

def _leaf_to_dict(sub, elide_defaults: bool) -> dict:
    out = {}
    for f in dataclasses.fields(sub):
        v = getattr(sub, f.name)
        if elide_defaults and v == f.default:
            continue
        out[f.name] = v
    return out


def to_dict(spec: ExperimentSpec, *, elide_defaults: bool = True) -> dict:
    """Nested plain-dict form.  With ``elide_defaults`` (the default) every
    field equal to its dataclass default is dropped — the dict names only
    what the experiment *chose*, so diffs and manifests stay readable and
    old manifests keep loading when new defaulted fields appear."""
    out = {}
    for name in _SECTION_TYPES:
        d = _leaf_to_dict(getattr(spec, name), elide_defaults)
        if d or not elide_defaults:
            out[name] = d
    return out


def _leaf_from_dict(cls, d: Mapping, where: str):
    if not isinstance(d, Mapping):
        raise TypeError(f"{where}: expected a mapping, got {type(d).__name__}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise KeyError(f"{where}: unknown key(s) {sorted(unknown)} "
                       f"(known: {sorted(known)})")
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        # JSON round-trips ints for float fields (e.g. beta: 1) — normalize
        # so from_dict(to_dict(s)) == s holds through a json.dumps cycle.
        if f.type in ("float", "Optional[float]", float) \
                and isinstance(v, int) and not isinstance(v, bool):
            v = float(v)
        kwargs[f.name] = v
    return cls(**kwargs)


def from_dict(d: Mapping) -> ExperimentSpec:
    """Strict inverse of :func:`to_dict`: unknown keys raise (at every
    level), missing keys take the dataclass default."""
    if not isinstance(d, Mapping):
        raise TypeError(f"spec: expected a mapping, got {type(d).__name__}")
    unknown = set(d) - set(_SECTION_TYPES)
    if unknown:
        raise KeyError(f"spec: unknown section(s) {sorted(unknown)} "
                       f"(known: {sorted(_SECTION_TYPES)})")
    kwargs = {name: _leaf_from_dict(cls, d[name], name)
              for name, cls in _SECTION_TYPES.items() if name in d}
    return ExperimentSpec(**kwargs)


def to_json(spec: ExperimentSpec, *, elide_defaults: bool = True,
            indent: int | None = 1) -> str:
    return json.dumps(to_dict(spec, elide_defaults=elide_defaults),
                      indent=indent, sort_keys=True)


def from_json(text: str) -> ExperimentSpec:
    return from_dict(json.loads(text))


def load(path: str) -> ExperimentSpec:
    """Load a spec (or a manifest wrapping one under a ``"spec"`` key —
    only the known manifest format is unwrapped; anything else errors)."""
    with open(path) as f:
        d = json.load(f)
    if isinstance(d, Mapping) and "format" in d:
        from .manifest import MANIFEST_FORMAT  # deferred: manifest imports us
        if d["format"] != MANIFEST_FORMAT:
            raise ValueError(f"{path}: unsupported manifest format "
                             f"{d['format']!r} (want {MANIFEST_FORMAT!r})")
        d = d.get("spec", {})
    return from_dict(d)


def spec_hash(spec: ExperimentSpec) -> str:
    """Short stable content hash of the fully-resolved spec — the scenario
    identity used by BENCH rows and manifests.  The spec is normalized
    through ``from_dict`` first so equal specs hash equally even when a
    float field was populated with a Python int (json would emit ``1`` vs
    ``1.0`` and split the hash)."""
    canon_spec = from_dict(to_dict(spec, elide_defaults=False))
    canon = json.dumps(to_dict(canon_spec, elide_defaults=False),
                       sort_keys=True)
    return hashlib.sha256(canon.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# Dotted-path overrides and grid expansion
# ---------------------------------------------------------------------------

def with_field(spec: ExperimentSpec, path: str, value) -> ExperimentSpec:
    """Return ``spec`` with one dotted-path field replaced, e.g.
    ``with_field(s, "algorithm.name", "dsgd")``."""
    section, _, field = path.partition(".")
    if section not in _SECTION_TYPES or not field:
        raise KeyError(f"bad override path {path!r} (want "
                       f"'<section>.<field>', sections: "
                       f"{sorted(_SECTION_TYPES)})")
    sub = getattr(spec, section)
    if field not in {f.name for f in dataclasses.fields(sub)}:
        raise KeyError(f"unknown field {field!r} in section {section!r}")
    return dataclasses.replace(spec, **{
        section: dataclasses.replace(sub, **{field: value})})


def with_overrides(spec: ExperimentSpec,
                   overrides: Mapping[str, Any]) -> ExperimentSpec:
    for path, value in overrides.items():
        spec = with_field(spec, path, value)
    return spec


def sweep(base: ExperimentSpec,
          overrides: Mapping[str, Sequence]) -> list[ExperimentSpec]:
    """Grid-expand ``base`` over per-field value lists: the cartesian
    product of every ``{"section.field": [v0, v1, ...]}`` axis, in
    deterministic (insertion x value) order.

        sweep(base, {"algorithm.name": ["dsgd", "mc_dsgt"],
                     "channel.link_drop": [0.0, 0.2]})   # 4 specs
    """
    paths = list(overrides)
    grids = [list(overrides[p]) for p in paths]
    out = []
    for combo in itertools.product(*grids):
        out.append(with_overrides(base, dict(zip(paths, combo))))
    return out
