"""The one progress-output helper for examples and CLIs.

Everything user-facing that used to be a bare ``print(...)`` routes through
a :class:`Console` so (a) ``--quiet`` silences progress chatter in one
place, and (b) structured progress lines stay machine-parseable:
``Console.event`` emits ``name key=value key=value ...`` with stable
formatting, and can mirror the same record into a metrics sink.
"""

from __future__ import annotations

import sys
from typing import Optional


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


class Console:
    """Progress printer with a ``--quiet`` switch and optional sink mirror.

    ``print`` is free-form text (suppressed when quiet); ``event`` is one
    machine-parseable ``name k=v ...`` line, optionally mirrored into
    ``sink`` (a :class:`repro.obs.metrics.MetricsSink`) as
    ``{"event": name, **fields}`` so a run's stdout and its event log
    agree.
    """

    def __init__(self, quiet: bool = False, sink=None, stream=None):
        self.quiet = bool(quiet)
        self.sink = sink
        self.stream = stream if stream is not None else sys.stdout

    @classmethod
    def from_argv(cls, argv=None) -> "Console":
        argv = sys.argv[1:] if argv is None else argv
        return cls(quiet=("--quiet" in argv or "-q" in argv))

    def print(self, *args, **kwargs):
        if not self.quiet:
            print(*args, file=self.stream, **kwargs)

    def event(self, name: str, **fields):
        if self.sink is not None:
            self.sink.emit({"event": name, **fields})
        if not self.quiet:
            parts = [name] + [f"{k}={_fmt(v)}" for k, v in fields.items()]
            print(" ".join(parts), file=self.stream)

    def rule(self, title: Optional[str] = None, width: int = 64):
        if self.quiet:
            return
        if title:
            pad = max(0, width - len(title) - 4)
            print(f"-- {title} {'-' * pad}", file=self.stream)
        else:
            print("-" * width, file=self.stream)
