"""Phase spans and the opt-in jax profiler trace.

The driver loop (:func:`repro.core.driver.run_loop`) has four host-visible
phases per step — ``data`` (batch/key production), ``step`` (the jitted
dispatch), ``telemetry`` (the record hook) and ``checkpoint``.  A
:class:`Tracer` wraps each in a wall-clock span plus a
``jax.profiler.TraceAnnotation`` so the same labels show up in a profiler
timeline.  The grad/mix *sub*-phases live inside one fused jit and cannot
be wall-clocked from the host; the engine tags them with
``jax.named_scope("obs_grad"/"obs_mix")`` instead, which the profiler
trace (:class:`Profiler`, ``--profile-dir``) decomposes.

:func:`overlap_report` reads those same tags out of a step's jaxpr to
*prove* (or refute) overlap-eligibility: under stale-window gossip
(``AlgorithmSpec.delay > 0``) no ``obs_mix`` operation may transitively
consume an ``obs_grad`` output, so XLA's latency-hiding scheduler is free
to run the gossip collectives concurrently with the grad computation.
"""

from __future__ import annotations

import time

import jax

PHASES = ("data", "step", "telemetry", "checkpoint")


# ---------------------------------------------------------------------------
# Overlap verification: data-dependence between the obs_grad / obs_mix tags
# ---------------------------------------------------------------------------

def _eqn_scopes(eqn) -> str:
    """The named_scope stack an equation was traced under, as a string
    (e.g. ``'obs_mix/transpose[...]'``)."""
    try:
        return str(eqn.source_info.name_stack)
    except AttributeError:  # very old jax: no name stacks — report nothing
        return ""


def mix_depends_on_grad(jaxpr) -> bool:
    """Whether any ``obs_mix``-tagged equation of ``jaxpr`` transitively
    consumes a value produced under ``obs_grad``.

    Taint propagation over the (topologically ordered) equation list,
    treating each equation atomically: an equation whose inputs carry
    taint taints all its outputs.  Sub-jaxprs (scan/cond bodies) inherit
    the outer equation's name stack, so outer-equation granularity is a
    sound over-approximation.  False means the mix is data-independent of
    the step's gradient — the XLA scheduler MAY overlap them (the
    ``delay > 0`` contract); True means the mix serializes after the grad
    (every synchronous rule, where the mix payload contains the fresh
    update).
    """
    closed = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    tainted: set = set()
    for eqn in closed.eqns:
        scopes = _eqn_scopes(eqn)
        consumes = any(not isinstance(v, jax.core.Literal) and v in tainted
                       for v in eqn.invars)
        if "obs_mix" in scopes and consumes:
            return True
        if "obs_grad" in scopes or consumes:
            tainted.update(eqn.outvars)
    return False


def overlap_report(fn, *args, **kwargs) -> dict:
    """Trace ``fn(*args, **kwargs)`` (abstractly — nothing executes) and
    report whether its gossip mix is overlap-eligible:

    * ``overlapped``  — True when no ``obs_mix`` op transitively depends
      on an ``obs_grad`` output (the stale-window double-buffer contract);
    * ``mix_eqns`` / ``grad_eqns`` — tagged top-level equation counts
      (0 for both means the function was not engine-annotated and the
      verdict is vacuous).
    """
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    closed = getattr(jaxpr, "jaxpr", jaxpr)
    mix_eqns = sum(1 for e in closed.eqns if "obs_mix" in _eqn_scopes(e))
    grad_eqns = sum(1 for e in closed.eqns if "obs_grad" in _eqn_scopes(e))
    return {"overlapped": not mix_depends_on_grad(jaxpr),
            "mix_eqns": mix_eqns, "grad_eqns": grad_eqns}


class Tracer:
    """Wall-clock phase spans for the driver loop.

    ``span(phase)`` is a context manager; completed spans accumulate into
    ``totals``/``counts`` and queue in ``_pending`` until the next
    :meth:`drain` (the ObsRecorder attaches them to that step's event).

    ``annotate=True`` additionally wraps each span in a
    ``jax.profiler.TraceAnnotation`` so the labels land in a profiler
    timeline; it is off by default because the annotation costs a few
    microseconds per span on the hot path and is only readable when a
    trace (``--profile-dir``) is actually being captured.
    """

    def __init__(self, annotate: bool = False):
        self.annotate = annotate
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}
        self._pending: dict[str, float] = {}
        self._spans: dict[str, _Span] = {}

    def span(self, phase: str) -> "_Span":
        # One reusable context-manager object per phase: span() runs every
        # loop phase of every step, so it avoids allocating a generator
        # frame per call.  Phases never nest, so reuse is safe.
        s = self._spans.get(phase)
        if s is None:
            s = self._spans[phase] = _Span(self, phase)
        return s

    def drain(self) -> dict[str, float]:
        """Spans accumulated since the last drain (one step's worth)."""
        out, self._pending = self._pending, {}
        return out

    def summary(self) -> dict:
        """Per-phase totals for the run-summary event / report table."""
        return {
            phase: {"total_sec": self.totals[phase],
                    "count": self.counts.get(phase, 0),
                    "mean_ms": 1e3 * self.totals[phase]
                    / max(1, self.counts.get(phase, 0))}
            for phase in sorted(self.totals)
        }


class _Span:
    """Reusable timing context for one Tracer phase (see Tracer.span)."""

    __slots__ = ("tracer", "phase", "ann", "t0")

    def __init__(self, tracer: Tracer, phase: str):
        self.tracer = tracer
        self.phase = phase
        self.ann = None
        self.t0 = 0.0

    def __enter__(self):
        if self.tracer.annotate:
            self.ann = jax.profiler.TraceAnnotation(f"obs:{self.phase}")
            self.ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        dt = time.perf_counter() - self.t0
        tr, ph = self.tracer, self.phase
        tr.totals[ph] = tr.totals.get(ph, 0.0) + dt
        tr.counts[ph] = tr.counts.get(ph, 0) + 1
        tr._pending[ph] = tr._pending.get(ph, 0.0) + dt
        if self.ann is not None:
            ann, self.ann = self.ann, None
            ann.__exit__(et, ev, tb)
        return False


class Profiler:
    """Opt-in jax profiler trace of the first ``steps`` recorded steps.

    ``start()`` before the loop, ``maybe_stop(k)`` from the record hook
    (stops once ``steps`` steps have been observed), ``close()`` as a
    stop-on-exit guard.  Dumps a TensorBoard-loadable trace into ``dir``.
    """

    def __init__(self, directory: str, steps: int = 8):
        self.dir = directory
        self.steps = int(steps)
        self._active = False
        self._seen = 0

    def start(self):
        if not self._active:
            jax.profiler.start_trace(self.dir)
            self._active = True
        return self

    def maybe_stop(self, k: int) -> bool:
        """Count one recorded step; stop the trace after ``steps``."""
        del k
        if not self._active:
            return False
        self._seen += 1
        if self._seen >= self.steps:
            self.close()
            return True
        return False

    def close(self):
        if self._active:
            self._active = False
            jax.profiler.stop_trace()
