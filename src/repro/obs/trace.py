"""Phase spans and the opt-in jax profiler trace.

The driver loop (:func:`repro.core.driver.run_loop`) has four host-visible
phases per step — ``data`` (batch/key production), ``step`` (the jitted
dispatch), ``telemetry`` (the record hook) and ``checkpoint``.  A
:class:`Tracer` wraps each in a wall-clock span plus a
``jax.profiler.TraceAnnotation`` so the same labels show up in a profiler
timeline.  The grad/mix *sub*-phases live inside one fused jit and cannot
be wall-clocked from the host; the engine tags them with
``jax.named_scope("obs_grad"/"obs_mix")`` instead, which the profiler
trace (:class:`Profiler`, ``--profile-dir``) decomposes.
"""

from __future__ import annotations

import time

import jax

PHASES = ("data", "step", "telemetry", "checkpoint")


class Tracer:
    """Wall-clock phase spans for the driver loop.

    ``span(phase)`` is a context manager; completed spans accumulate into
    ``totals``/``counts`` and queue in ``_pending`` until the next
    :meth:`drain` (the ObsRecorder attaches them to that step's event).

    ``annotate=True`` additionally wraps each span in a
    ``jax.profiler.TraceAnnotation`` so the labels land in a profiler
    timeline; it is off by default because the annotation costs a few
    microseconds per span on the hot path and is only readable when a
    trace (``--profile-dir``) is actually being captured.
    """

    def __init__(self, annotate: bool = False):
        self.annotate = annotate
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}
        self._pending: dict[str, float] = {}
        self._spans: dict[str, _Span] = {}

    def span(self, phase: str) -> "_Span":
        # One reusable context-manager object per phase: span() runs every
        # loop phase of every step, so it avoids allocating a generator
        # frame per call.  Phases never nest, so reuse is safe.
        s = self._spans.get(phase)
        if s is None:
            s = self._spans[phase] = _Span(self, phase)
        return s

    def drain(self) -> dict[str, float]:
        """Spans accumulated since the last drain (one step's worth)."""
        out, self._pending = self._pending, {}
        return out

    def summary(self) -> dict:
        """Per-phase totals for the run-summary event / report table."""
        return {
            phase: {"total_sec": self.totals[phase],
                    "count": self.counts.get(phase, 0),
                    "mean_ms": 1e3 * self.totals[phase]
                    / max(1, self.counts.get(phase, 0))}
            for phase in sorted(self.totals)
        }


class _Span:
    """Reusable timing context for one Tracer phase (see Tracer.span)."""

    __slots__ = ("tracer", "phase", "ann", "t0")

    def __init__(self, tracer: Tracer, phase: str):
        self.tracer = tracer
        self.phase = phase
        self.ann = None
        self.t0 = 0.0

    def __enter__(self):
        if self.tracer.annotate:
            self.ann = jax.profiler.TraceAnnotation(f"obs:{self.phase}")
            self.ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        dt = time.perf_counter() - self.t0
        tr, ph = self.tracer, self.phase
        tr.totals[ph] = tr.totals.get(ph, 0.0) + dt
        tr.counts[ph] = tr.counts.get(ph, 0) + 1
        tr._pending[ph] = tr._pending.get(ph, 0.0) + dt
        if self.ann is not None:
            ann, self.ann = self.ann, None
            ann.__exit__(et, ev, tb)
        return False


class Profiler:
    """Opt-in jax profiler trace of the first ``steps`` recorded steps.

    ``start()`` before the loop, ``maybe_stop(k)`` from the record hook
    (stops once ``steps`` steps have been observed), ``close()`` as a
    stop-on-exit guard.  Dumps a TensorBoard-loadable trace into ``dir``.
    """

    def __init__(self, directory: str, steps: int = 8):
        self.dir = directory
        self.steps = int(steps)
        self._active = False
        self._seen = 0

    def start(self):
        if not self._active:
            jax.profiler.start_trace(self.dir)
            self._active = True
        return self

    def maybe_stop(self, k: int) -> bool:
        """Count one recorded step; stop the trace after ``steps``."""
        del k
        if not self._active:
            return False
        self._seen += 1
        if self._seen >= self.steps:
            self.close()
            return True
        return False

    def close(self):
        if self._active:
            self._active = False
            jax.profiler.stop_trace()
