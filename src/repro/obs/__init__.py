"""repro.obs — unified observability for both runtimes.

The paper's contribution is a *complexity* statement (iterations x
communication to reach ε-stationarity); this package is the measurement
layer that lets the repo see its own complexity:

* :mod:`repro.obs.metrics` — the :class:`MetricsSink` protocol with a JSONL
  :class:`EventLog` backend, and the :class:`ObsRecorder` driver hook that
  batches the engine's in-jit step scalars (grad norm, consensus distance,
  mixing residual, tracker drift — computed once in
  :mod:`repro.core.engine` for BOTH runtimes) and flushes them host-side
  every ``every`` steps, so observation adds no device syncs to the hot
  path;
* :mod:`repro.obs.trace` — per-phase wall-clock spans
  (data/step/telemetry/checkpoint) wrapping
  ``jax.profiler.TraceAnnotation``, plus the opt-in ``--profile-dir``
  N-step jax profiler trace;
* :mod:`repro.obs.optimality` — online optimality-gap tracking of the
  measured ||∇f||² trajectory against the paper's lower bound
  (:mod:`repro.core.lower_bound`) per (algorithm x topology-class x
  channel) cell;
* :mod:`repro.obs.report` — ``python -m repro.obs.report <log.jsonl>``
  renders the run summary (phase table, metric sparklines, optimality
  gap);
* :mod:`repro.obs.console` — the one progress-output helper (honors
  ``--quiet``, keeps stdout machine-parseable).

Enable it declaratively: ``ExperimentSpec(obs=ObsSpec(metrics="run.jsonl"))``
or ``launch/train.py --metrics run.jsonl [--metrics-every N]
[--profile-dir DIR]``.
"""

from .console import Console  # noqa: F401
from .metrics import (  # noqa: F401
    EVENT_FIELDS,
    OBS_METRICS,
    ChainSink,
    EventLog,
    MemorySink,
    MetricsSink,
    ObsRecorder,
    read_events,
)
from .optimality import GapTracker, cell_key, theoretical_floor  # noqa: F401
from .trace import (  # noqa: F401
    PHASES,
    Profiler,
    Tracer,
    mix_depends_on_grad,
    overlap_report,
)
