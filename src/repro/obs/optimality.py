"""Online optimality-gap tracking against the paper's lower bound.

The paper (Theorem 4, via the zero-chain instances in
:mod:`repro.core.lower_bound`) shows that ANY algorithm in the class must
satisfy, after a budget of ``T`` oracle/gossip rounds over a network with
mixing parameter ``beta``::

    min_t E||grad f(x_t)||^2  >=  c1 * sqrt(Delta L sigma^2 / (n T))
                                + c2 * Delta L / ((1 - beta) T)

(statistical term + network term).  A :class:`GapTracker` consumes the
measured ``grad_norm`` series (fed by the
:class:`repro.obs.metrics.ObsRecorder` flush) and reports, per
(algorithm x topology-class x channel) *cell*, how far the run's best
measured squared gradient norm sits above that floor — the repo's
empirical read on the paper's "optimal complexity" claim.

The floor is a *scaling* statement: absolute constants are unity here, so
``gap_ratio`` is meaningful for comparing cells and tracking progress, not
as a certified constant-sharp bound.  ``fit_rate`` estimates the empirical
decay slope d log(min-so-far) / d log(T) to compare against the bound's
-1/2 (statistical regime) and -1 (network regime) exponents.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional

from ..core import lower_bound as lb


def theoretical_floor(T: float, *, n: int, beta: float, L: float = 1.0,
                      Delta: float = 1.0, sigma: float = 1.0) -> float:
    """The Theorem 4 floor on min E||grad f||^2 after budget T (unit
    constants).  ``sigma=0`` drops the statistical term (full-batch
    oracles); ``beta`` is the schedule's mixing parameter (0 = perfect
    mixing, 1 = never mixes — the network term diverges)."""
    T = max(float(T), 1.0)
    stat = math.sqrt(Delta * L * sigma ** 2 / (n * T)) if sigma > 0 else 0.0
    net = Delta * L / ((1.0 - min(beta, 1.0 - 1e-12)) * T)
    return stat + net


def statistical_term(T: float, *, n: int, L: float = 1.0, Delta: float = 1.0,
                     sigma: float = 1.0) -> float:
    return theoretical_floor(T, n=n, beta=0.0, L=L, Delta=Delta,
                             sigma=sigma) - Delta * L / max(float(T), 1.0)


# Named bounds a report can cite.  Each maps (T, n, beta, L, Delta, sigma)
# -> floor value; "paper" is Theorem 4 (the tight one — matched by
# MC-DSGT up to constants/log factors), "centralized" is the beta-free
# sqrt(DeltaL sigma^2 / nT) reference (what perfect mixing would allow).
BOUNDS: Dict[str, Callable[..., float]] = {
    "paper": lambda T, n, beta, L=1.0, Delta=1.0, sigma=1.0:
        theoretical_floor(T, n=n, beta=beta, L=L, Delta=Delta, sigma=sigma),
    "centralized": lambda T, n, beta, L=1.0, Delta=1.0, sigma=1.0:
        theoretical_floor(T, n=n, beta=0.0, L=L, Delta=Delta, sigma=sigma),
}

# Tie to the hard-instance constants so the report can say which regime the
# adversarial constructions would pin (Appendix B).
INSTANCE_CONSTANTS = {"DELTA0": lb.DELTA0, "ELL0": lb.ELL0, "G_INF": lb.G_INF}


def cell_key(algo: str, topology: Optional[str] = None,
             channel: Optional[str] = None) -> str:
    """The (algorithm x topology-class x channel) cell label the gap is
    tracked per.  ``channel=None`` means the ideal (lossless) channel."""
    return f"{algo}/{topology or 'static'}/{channel or 'ideal'}"


def fit_rate(ts, vals) -> Optional[float]:
    """Least-squares slope of log(val) vs log(T) — the empirical decay
    exponent.  None when fewer than 3 usable points."""
    pts = [(math.log(t), math.log(v)) for t, v in zip(ts, vals)
           if t > 0 and v > 0]
    if len(pts) < 3:
        return None
    mx = sum(x for x, _ in pts) / len(pts)
    my = sum(y for _, y in pts) / len(pts)
    den = sum((x - mx) ** 2 for x, _ in pts)
    if den <= 0:
        return None
    return sum((x - mx) * (y - my) for x, y in pts) / den


class GapTracker:
    """Running min ||grad f||^2 vs the lower-bound floor for one cell.

    ``update(t, gnorm2)`` is fed by the ObsRecorder flush with the
    measured squared gradient norm at budget ``t``; the tracker keeps the
    best-so-far trajectory (the quantity the bound constrains) downsampled
    to ``max_points`` for the rate fit.
    """

    def __init__(self, *, cell: str, n: int, beta: float, L: float = 1.0,
                 Delta: float = 1.0, sigma: float = 1.0,
                 bound: str = "paper", max_points: int = 512):
        if bound not in BOUNDS:
            raise ValueError(f"unknown bound {bound!r}; "
                             f"known: {sorted(BOUNDS)}")
        self.cell = cell
        self.n = int(n)
        self.beta = float(beta)
        self.L, self.Delta, self.sigma = float(L), float(Delta), float(sigma)
        self.bound = bound
        self.max_points = int(max_points)
        self.T = 0
        self.best: Optional[float] = None
        self._traj: list[tuple[int, float]] = []  # (t, best-so-far)

    def update(self, t: int, gnorm2: float) -> None:
        gnorm2 = float(gnorm2)
        if not math.isfinite(gnorm2):
            return
        self.T = max(self.T, int(t))
        if self.best is None or gnorm2 < self.best:
            self.best = gnorm2
        self._traj.append((int(t), self.best))
        if len(self._traj) > 2 * self.max_points:
            self._traj = self._traj[:: 2]

    def floor(self, T: Optional[int] = None) -> float:
        return BOUNDS[self.bound](T if T is not None else self.T, self.n,
                                  self.beta, self.L, self.Delta, self.sigma)

    def summary(self) -> dict:
        """{cell, T, n, beta, floor, best, gap_ratio, rate_slope} — the
        per-cell record the summary event and report render."""
        floor = self.floor() if self.T else None
        gap = (self.best / floor if self.best is not None and floor
               else None)
        return {
            "cell": self.cell, "bound": self.bound,
            "T": self.T, "n": self.n, "beta": round(self.beta, 6),
            "floor": floor, "best_grad_sq": self.best,
            "gap_ratio": gap,
            "rate_slope": fit_rate(*zip(*self._traj)) if self._traj else None,
        }
