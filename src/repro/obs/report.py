"""Render a run summary from a JSONL event log.

``python -m repro.obs.report <log.jsonl>`` prints the run header, the
per-phase wall-clock table, per-metric stats with a unicode sparkline of
the series, and the optimality-gap section (measured best ||grad f||^2 vs
the paper's lower-bound floor for the run's cell).  Everything is computed
from the log alone — no jax, no re-execution — so it works on logs shipped
as CI artifacts.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from .metrics import OBS_METRICS, read_events

_BARS = "▁▂▃▄▅▆▇█"


def sparkline(vals, width: int = 32) -> str:
    """Downsample ``vals`` to ``width`` buckets of unicode bars."""
    vals = [v for v in vals if v is not None]
    if not vals:
        return ""
    if len(vals) > width:
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return _BARS[0] * len(vals)
    return "".join(_BARS[min(len(_BARS) - 1,
                             int((v - lo) / (hi - lo) * len(_BARS)))]
                   for v in vals)


def _fmt(v, nd: int = 4) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def _series(steps, key):
    return [s[key] for s in steps if s.get(key) is not None]


def _stats(vals) -> Optional[dict]:
    if not vals:
        return None
    return {"first": vals[0], "last": vals[-1],
            "min": min(vals), "max": max(vals), "n": len(vals)}


def render(events: list, width: int = 32) -> str:
    """The full text report for one event log."""
    meta = next((e for e in events if e.get("event") == "meta"), {})
    steps = [e for e in events if e.get("event") == "step"]
    evals = [e for e in events if e.get("event") == "eval"]
    summary = next((e for e in events if e.get("event") == "summary"), {})
    lines: list[str] = []

    title = meta.get("name") or meta.get("algo") or "run"
    lines.append(f"== repro.obs report: {title} ==")
    head = {k: v for k, v in meta.items()
            if k not in ("event", "name") and not isinstance(v, (dict, list))}
    if head:
        lines.append("  " + "  ".join(f"{k}={_fmt(v)}"
                                      for k, v in sorted(head.items())))
    if steps:
        secs = _series(steps, "sec")
        lines.append(f"  steps recorded: {len(steps)}   "
                     f"T: {steps[-1].get('t', '-')}   "
                     f"step time: {_fmt(sum(secs) / len(secs))}s mean"
                     if secs else f"  steps recorded: {len(steps)}")

    phases = summary.get("phases") or {}
    if phases:
        lines.append("")
        lines.append("-- phases " + "-" * (width + 18))
        lines.append(f"  {'phase':<12}{'total s':>10}{'calls':>8}"
                     f"{'mean ms':>10}")
        for name, p in sorted(phases.items(),
                              key=lambda kv: -kv[1]["total_sec"]):
            lines.append(f"  {name:<12}{p['total_sec']:>10.4f}"
                         f"{p['count']:>8}{p['mean_ms']:>10.3f}")

    metric_keys = ["loss", *OBS_METRICS]
    shown = [k for k in metric_keys if _series(steps, k)]
    if shown:
        lines.append("")
        lines.append("-- metrics " + "-" * (width + 17))
        for key in shown:
            vals = _series(steps, key)
            st = _stats(vals)
            lines.append(f"  {key:<17} {sparkline(vals, width):<{width}} "
                         f"last={_fmt(st['last'])} min={_fmt(st['min'])} "
                         f"max={_fmt(st['max'])}")
    if evals:
        vals = [e["value"] for e in evals]
        st = _stats(vals)
        lines.append(f"  {'eval':<17} {sparkline(vals, width):<{width}} "
                     f"last={_fmt(st['last'])} min={_fmt(st['min'])} "
                     f"max={_fmt(st['max'])}")

    opt = summary.get("optimality")
    if opt:
        lines.append("")
        lines.append("-- optimality gap " + "-" * (width + 10))
        lines.append(f"  cell: {opt.get('cell', '-')}   "
                     f"bound: {opt.get('bound', 'paper')}   "
                     f"n={opt.get('n', '-')} beta={_fmt(opt.get('beta'))}")
        lines.append(f"  T={opt.get('T', '-')}   "
                     f"floor={_fmt(opt.get('floor'))}   "
                     f"best ||grad f||^2={_fmt(opt.get('best_grad_sq'))}")
        gap = opt.get("gap_ratio")
        slope = opt.get("rate_slope")
        lines.append(f"  gap ratio (measured / floor): {_fmt(gap)}   "
                     f"empirical slope d log/d logT: {_fmt(slope)}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run summary from a repro.obs JSONL event log")
    ap.add_argument("log", help="path to the .jsonl event log")
    ap.add_argument("--width", type=int, default=32,
                    help="sparkline width (default 32)")
    ap.add_argument("--json", action="store_true",
                    help="dump the summary event as JSON instead")
    args = ap.parse_args(argv)
    events = read_events(args.log)
    try:
        if args.json:
            summary = next((e for e in events
                            if e.get("event") == "summary"), {})
            print(json.dumps(summary, indent=1))
        else:
            print(render(events, width=args.width))
    except BrokenPipeError:  # e.g. piped into head
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
