"""Metric sinks and the batched in-jit-metrics recorder.

The actual metric arithmetic lives in :mod:`repro.core.engine`
(:data:`~repro.core.engine.OBS_METRICS` — grad norm, consensus distance,
mixing residual, tracker residual), computed INSIDE the jitted step of
both runtimes as a dict of device scalars.  This module is the host side:
a :class:`MetricsSink` protocol with the JSONL :class:`EventLog` backend,
and the :class:`ObsRecorder` that plugs into the driver's ``record`` hook,
buffers the device scalars, and hands each ``every``-step batch to a
background flusher thread that crosses the host boundary with a single
batched ``jax.device_get`` — the hot path never gains a per-step sync or
transfer.

Event-log schema (one JSON object per line)::

    {"event": "meta", ...}      run header (spec hash, algo, n, cell, ...)
    {"event": "step", ...}      per-step metrics (see EVENT_FIELDS)
    {"event": "eval", ...}      eval_fn points (k, t, value)
    {"event": "summary", ...}   end-of-run phase totals + optimality gap
"""

from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Iterable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core import engine

# Host-facing vocabulary: one description per engine metric.  Kept in lock
# step with the engine (test-enforced) so registries can validate names.
OBS_METRICS = {
    "grad_norm": "||g||_F of the stacked per-node oracle gradients "
                 "(f32 accumulation)",
    "consensus": "consensus distance ||x - x_bar||_F of the post-step "
                 "stacked iterate",
    "mix_residual": "||x_post - x_pre||_F across the step's gossip "
                    "mixing (0 when the realized window did not move "
                    "the state)",
    "tracker_residual": "||mean_i h_i - mean_i g_i||_F — drift of the "
                        "gradient-tracking invariant mean(h) = mean(g) "
                        "(clipping / low-precision trackers / channel "
                        "repair make this nonzero)",
}
assert tuple(OBS_METRICS) == engine.OBS_METRICS

EVENT_FIELDS = {
    "event": "record type: meta | step | eval | summary",
    "step": "driver step index k",
    "t": "total gossip/oracle budget T consumed after this step",
    "sec": "wall-clock seconds of the step dispatch",
    "loss": "runtime scalar loss when the step reports one",
    **OBS_METRICS,
    "phases": "wall-clock seconds per driver phase since the previous "
              "record (data/step/telemetry/checkpoint)",
    "spectral_gap": "realized-window mixing contraction (from the chained "
                    "TelemetryRecorder, when present)",
    "eff_diameter": "realized-window effective diameter (chained "
                    "TelemetryRecorder)",
    "kinds": "realized plan-kind counts (chained TelemetryRecorder)",
    "bytes": "wire bytes this step's realized gossip transmitted — the "
             "compressed payload format once past warmup (chained "
             "TelemetryRecorder)",
    "bytes_total": "cumulative wire bytes since step 0 (chained "
                   "TelemetryRecorder)",
    "value": "eval_fn(x_bar) at an eval event",
}

# Keys the chained TelemetryRecorder contributes to a step event (its
# step/t/loss/sec/consensus duplicates the recorder's own fields).
_TELEMETRY_KEYS = ("window", "spectral_gap", "eff_diameter", "kinds",
                   "bytes", "bytes_total")


@runtime_checkable
class MetricsSink(Protocol):
    """Anything that accepts event dicts: ``emit(event)`` + ``close()``."""

    def emit(self, event: dict) -> None: ...

    def close(self) -> None: ...


class EventLog:
    """Append-only JSONL sink.  Opens lazily (and mkdir -p's the parent)
    on the first emit, so constructing a spec never touches the fs."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def emit(self, event: dict) -> None:
        if self._f is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "w")
        self._f.write(json.dumps(event, default=_jsonable) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class MemorySink:
    """In-process sink (tests, notebooks): events land in ``.events``."""

    def __init__(self):
        self.events: list[dict] = []
        self.closed = False

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        self.closed = True


class ChainSink:
    """Fan one emit out to several sinks."""

    def __init__(self, *sinks: MetricsSink):
        self.sinks = tuple(s for s in sinks if s is not None)

    def emit(self, event: dict) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


# Jitted scalar packing for the flush transfer; retraces only when the
# batch size changes (the tail flush), so steady state is one cached call.
@jax.jit
def _pack(leaves):
    return jnp.stack([jnp.asarray(v, jnp.float32) for v in leaves])


def _jsonable(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


def read_events(path: str, kind: Optional[str] = None) -> list[dict]:
    """Load a JSONL event log (optionally filtered to one event kind)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if kind is None or ev.get("event") == kind:
                out.append(ev)
    return out


class ObsRecorder:
    """The driver ``record`` hook that turns in-jit obs scalars into events.

    Plugs in wherever a :class:`repro.sim.telemetry.TelemetryRecorder`
    does (``record(k, t, state, out, dt)``); an existing TelemetryRecorder
    chains *through* it (``telemetry=``) rather than being replaced — its
    windowed mixing fields ride along on the step events and its own
    ``history``/``dump`` keep working.

    Per step this only appends to a host-side buffer; every ``every``
    recorded steps the buffered batch is handed to a background flusher
    thread, which moves the device scalars host-side in ONE batched
    ``jax.device_get`` (the buffered arrays are steps behind the dispatch
    frontier, so the copy does not stall the step pipeline) and feeds the
    sink / gap tracker off the hot path.  ``close()`` flushes the tail,
    joins the flusher, and emits the run ``summary`` event, so
    ``every > 1`` never loses events.  ``background=False`` flushes
    synchronously (deterministic interleaving for debugging).
    """

    def __init__(self, sink: MetricsSink, *, every: int = 10,
                 telemetry=None, tracer=None, gap=None, profiler=None,
                 meta: Optional[dict] = None, background: bool = True):
        self.sink = sink
        self.every = max(1, int(every))
        self.telemetry = telemetry
        self.tracer = tracer
        self.gap = gap
        self.profiler = profiler
        self.background = background
        self._buf: list[tuple] = []  # raw entries; see hook comment below
        self._closed = False
        self._queue: Optional[queue.SimpleQueue] = None
        self._worker: Optional[threading.Thread] = None
        self._worker_err: Optional[BaseException] = None
        if meta is not None:
            self.sink.emit({"event": "meta", **meta})

    # -- driver hooks -----------------------------------------------------
    #
    # The hot path appends raw tuples; the event dicts are built at drain
    # time (in the flusher thread under ``background=True``):
    #   ("step", k, t, dt, tl, phases, device)   device = {loss?, obs?}
    #   ("eval", k, t, value)

    def record(self, k: int, t: int, state: Any, out: Any,
               dt: float) -> Optional[dict]:
        tl = None
        if self.telemetry is not None:
            tl = self.telemetry.record(k, t, state, out, dt)
        phases = self.tracer.drain() if self.tracer is not None else None
        device = None
        if type(out) is dict:
            device = {kk: out[kk] for kk in ("loss", "obs") if kk in out
                      and out[kk] is not None}
        self._buf.append(("step", k, t, dt, tl, phases, device))
        if self.profiler is not None:
            self.profiler.maybe_stop(k)
        if len(self._buf) >= self.every:
            self.flush()
        return tl

    def eval_event(self, k: int, t: int, value) -> None:
        """An eval_fn point (already host-side in the driver)."""
        self._buf.append(("eval", k, t, float(value)))
        if len(self._buf) >= self.every:
            self.flush()

    # -- flushing ---------------------------------------------------------

    def flush(self) -> None:
        if not self._buf:
            return
        buf, self._buf = self._buf, []
        if self.background:
            if self._worker_err is not None:
                err, self._worker_err = self._worker_err, None
                raise err
            if self._worker is None:
                self._queue = queue.SimpleQueue()
                self._worker = threading.Thread(
                    target=self._drain_loop, name="obs-flush", daemon=True)
                self._worker.start()
            self._queue.put(buf)
        else:
            self._drain_batch(buf)

    def _drain_loop(self) -> None:
        while True:
            batch = self._queue.get()
            if batch is None:
                return
            try:
                self._drain_batch(batch)
            except BaseException as e:  # surfaced on the next flush/close
                self._worker_err = e

    def _drain_batch(self, buf) -> None:
        # One host transfer for the whole batch: stack every buffered
        # device scalar into a single array (one jitted call — op-by-op
        # jnp.stack would dispatch per element) when the dtypes allow it;
        # 50 tiny per-leaf copies cost ~10x one (50,) copy.
        devs = [e[6] for e in buf if e[0] == "step" and e[6] is not None]
        leaves, treedef = jax.tree.flatten(devs)
        try:
            flat = jax.device_get(_pack(leaves)) if leaves else []
        except (TypeError, ValueError):  # mixed dtypes/shapes: per-leaf
            flat = jax.device_get(leaves)
        host_iter = iter(jax.tree.unflatten(
            treedef, [float(v) for v in flat]))
        for entry in buf:
            if entry[0] == "eval":
                _, k, t, value = entry
                base = {"event": "eval", "step": int(k), "t": int(t),
                        "value": value}
            else:
                _, k, t, dt, tl, phases, device = entry
                base = {"event": "step", "step": int(k), "t": int(t),
                        "sec": round(float(dt), 6)}
                if tl:
                    base.update({kk: tl[kk] for kk in _TELEMETRY_KEYS
                                 if kk in tl})
                if phases:
                    base["phases"] = {p: round(v, 6)
                                      for p, v in phases.items()}
                if device is not None:
                    got = next(host_iter)
                    if "loss" in got:
                        base["loss"] = float(got["loss"])
                    for name, val in got.get("obs", {}).items():
                        base[name] = float(val)
                if self.gap is not None and "grad_norm" in base:
                    self.gap.update(base["t"], base["grad_norm"] ** 2)
            self.sink.emit(base)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.flush()
        if self._worker is not None:
            self._queue.put(None)
            self._worker.join()
            self._worker = None
            if self._worker_err is not None:
                raise self._worker_err
        summary: dict = {"event": "summary"}
        if self.tracer is not None:
            summary["phases"] = self.tracer.summary()
        if self.gap is not None:
            summary["optimality"] = self.gap.summary()
        self.sink.emit(summary)
        if self.profiler is not None:
            self.profiler.close()
        self.sink.close()

    # -- conveniences -----------------------------------------------------

    def emit(self, event: dict) -> None:
        """Pass-through for out-of-band events (meta, console mirrors)."""
        self.sink.emit(event)

    @property
    def history(self) -> list:
        """The chained TelemetryRecorder's history (empty when none)."""
        return self.telemetry.history if self.telemetry is not None else []

    def dump(self, path: str) -> None:
        if self.telemetry is not None:
            self.telemetry.dump(path)


def resolve_names(names, rule=None) -> tuple:
    """Normalize an obs metric selection to an engine-ready tuple.

    ``names`` is ``'auto'`` (the rule's default set — tracker residual only
    for tracking rules), a comma-separated string, an iterable of names, or
    None/'' (no metrics).  Unknown names raise with the vocabulary.
    """
    if names is None or names == "":
        return ()
    if names == "auto":
        return (engine.default_obs(rule) if rule is not None
                else engine.OBS_METRICS)
    if isinstance(names, str):
        names = tuple(s.strip() for s in names.split(",") if s.strip())
    names = tuple(names)
    bad = [n for n in names if n not in OBS_METRICS]
    if bad:
        raise ValueError(
            f"unknown obs metric(s) {bad}; known: {sorted(OBS_METRICS)}")
    return names
