"""Compatibility shims for the explicit-mesh jax API on jax 0.4.x.

The distributed runtime (:mod:`repro.dist`) and its tests are written
against the newer sharding surface:

* ``jax.set_mesh(mesh)`` context manager,
* ``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``,
* ``PartitionSpec``-valued ``in_shardings`` / ``out_shardings`` on
  ``jax.jit`` (resolved against the ambient mesh).

On jax versions that already provide these (>= 0.5-era explicit sharding)
this module is a no-op.  On 0.4.x each missing piece is emulated:
``set_mesh`` tracks the ambient mesh in a thread-local and enters the
legacy ``with mesh:`` context, and ``jax.jit`` is wrapped so PartitionSpec
entries in the shardings pytrees are bound to that mesh as NamedShardings
(jit then reshards mismatched committed inputs automatically).
"""

from __future__ import annotations

import contextlib
import functools
import inspect
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec

_state = threading.local()


def current_mesh():
    """The mesh installed by the (shimmed) ``jax.set_mesh``, or None."""
    return getattr(_state, "mesh", None)


# -- jax.sharding.AxisType ---------------------------------------------------

if not hasattr(jax.sharding, "AxisType"):
    class _AxisType:
        """Stand-in for jax.sharding.AxisType (all axes behave as Auto on
        0.4.x, which is what every mesh in this repo requests)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType


# -- jax.make_mesh(axis_types=...) -------------------------------------------

if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _orig_make_mesh = jax.make_mesh

    @functools.wraps(_orig_make_mesh)
    def _make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # Auto-only on 0.4.x
        return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh


# -- pallas TPU CompilerParams rename ----------------------------------------

try:
    from jax.experimental.pallas import tpu as _pltpu

    if not hasattr(_pltpu, "CompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
except ImportError:  # pragma: no cover
    pass


# -- jax.set_mesh + PartitionSpec shardings on jax.jit -----------------------

if not hasattr(jax, "set_mesh"):

    @contextlib.contextmanager
    def _set_mesh(mesh):
        prev = current_mesh()
        _state.mesh = mesh
        try:
            with mesh:
                yield mesh
        finally:
            _state.mesh = prev

    jax.set_mesh = _set_mesh

    _orig_jit = jax.jit

    def _bind_specs(mesh, tree):
        def conv(x):
            if isinstance(x, PartitionSpec):
                return NamedSharding(mesh, x)
            return x

        return jax.tree.map(
            conv, tree, is_leaf=lambda x: isinstance(x, PartitionSpec))

    @functools.wraps(_orig_jit)
    def _jit(fun=None, **kwargs):
        mesh = current_mesh()
        if mesh is not None:
            for name in ("in_shardings", "out_shardings"):
                if kwargs.get(name) is not None:
                    kwargs[name] = _bind_specs(mesh, kwargs[name])
        if fun is None:
            return functools.partial(_jit, **kwargs)
        return _orig_jit(fun, **kwargs)

    jax.jit = _jit
