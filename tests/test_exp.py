"""repro.exp front-door tests: strict spec (de)serialization, registry
single-sourcing, spec <-> CLI equivalence (bit-exact losses on dense AND
auto gossip paths), sweep expansion, and reproducibility manifests."""

import dataclasses
import json
import warnings

import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro import exp
from repro.launch import train


# ---------------------------------------------------------------------------
# Spec serialization
# ---------------------------------------------------------------------------

def test_default_spec_elides_to_empty():
    assert exp.to_dict(exp.ExperimentSpec()) == {}
    assert exp.from_dict({}) == exp.ExperimentSpec()


def test_to_dict_names_only_choices():
    s = exp.with_overrides(exp.ExperimentSpec(), {
        "algorithm.name": "dsgd", "channel.link_drop": 0.2})
    assert exp.to_dict(s) == {"algorithm": {"name": "dsgd"},
                              "channel": {"link_drop": 0.2}}


def test_roundtrip_json():
    s = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=8, m=32),
        data=exp.DataSpec(batch=4, hetero_alpha=0.3),
        algorithm=exp.AlgorithmSpec(name="gt_local", gamma=0.2),
        topology=exp.TopologySpec(kind="waypoint-mobility", radius=0.3),
        channel=exp.ChannelSpec(link_drop=0.2, burst_loss=0.1),
        run=exp.RunSpec(steps=3, nodes=8, gossip_impl="auto",
                        telemetry="t.json"))
    assert exp.from_json(exp.to_json(s)) == s
    # ...and through an actual json encode/decode cycle of the full form
    full = json.loads(json.dumps(exp.to_dict(s, elide_defaults=False)))
    assert exp.from_dict(full) == s


def test_unknown_keys_error():
    with pytest.raises(KeyError, match="unknown section"):
        exp.from_dict({"algorithmz": {}})
    with pytest.raises(KeyError, match="unknown key"):
        exp.from_dict({"algorithm": {"nme": "dsgd"}})
    with pytest.raises(KeyError, match="unknown key"):
        exp.from_dict({"run": {"steps": 2, "stepz": 3}})


def test_spec_hash_stable_and_sensitive():
    a, b = exp.ExperimentSpec(), exp.ExperimentSpec()
    assert exp.spec_hash(a) == exp.spec_hash(b)
    c = exp.with_field(a, "algorithm.name", "dsgd")
    assert exp.spec_hash(a) != exp.spec_hash(c)
    # an int-valued float field hashes like its serialized (float) form
    d = exp.with_field(a, "algorithm.gamma", 1)
    assert d == exp.from_dict(exp.to_dict(d))
    assert exp.spec_hash(d) == exp.spec_hash(exp.from_dict(exp.to_dict(d)))


if HAVE_HYPOTHESIS:
    _floats = st.floats(0.0, 1.0, allow_nan=False)
    _spec_strategy = st.builds(
        exp.ExperimentSpec,
        model=st.builds(exp.ModelRef,
                        kind=st.sampled_from(exp.MODEL_KINDS),
                        d=st.integers(1, 256), m=st.integers(1, 512),
                        rho=_floats),
        data=st.builds(exp.DataSpec, batch=st.integers(1, 8),
                       seq=st.integers(1, 128),
                       hetero_alpha=st.none() | st.floats(0.01, 10.0)),
        algorithm=st.builds(exp.AlgorithmSpec,
                            name=st.sampled_from(exp.ALGORITHMS),
                            gamma=_floats, R=st.integers(1, 4),
                            local_opt=st.sampled_from(
                                sorted(exp.LOCAL_OPTS))),
        topology=st.builds(exp.TopologySpec,
                           kind=st.sampled_from(sorted(exp.TOPOLOGIES)),
                           beta=_floats, er_p=_floats, radius=_floats,
                           local_steps=st.integers(1, 16)),
        channel=st.builds(exp.ChannelSpec, link_drop=_floats,
                          burst_loss=_floats, churn=_floats,
                          straggler=_floats),
        run=st.builds(exp.RunSpec, steps=st.integers(1, 100),
                      nodes=st.integers(1, 64), seed=st.integers(0, 2**31),
                      gossip_impl=st.sampled_from(exp.GOSSIP_IMPLS),
                      checkpoint=st.none() | st.just("ck.msgpack"),
                      telemetry=st.none() | st.just("telem.json")))
else:  # the _hyp stub makes @given skip; the strategy is never drawn
    _spec_strategy = None


@given(_spec_strategy)
@settings(max_examples=60, deadline=None)
def test_roundtrip_property(spec):
    """from_dict(to_dict(s)) == s over randomized specs, elided and full,
    including a real JSON encode/decode cycle."""
    assert exp.from_dict(exp.to_dict(spec)) == spec
    full = json.loads(json.dumps(exp.to_dict(spec, elide_defaults=False)))
    assert exp.from_dict(full) == spec
    assert exp.from_json(exp.to_json(spec)) == spec


# ---------------------------------------------------------------------------
# Overrides + sweep
# ---------------------------------------------------------------------------

def test_with_field_and_bad_paths():
    s = exp.with_field(exp.ExperimentSpec(), "run.steps", 7)
    assert s.run.steps == 7
    with pytest.raises(KeyError):
        exp.with_field(s, "run", 1)
    with pytest.raises(KeyError):
        exp.with_field(s, "runs.steps", 1)
    with pytest.raises(KeyError):
        exp.with_field(s, "run.stepz", 1)


def test_sweep_grid_order():
    grid = exp.sweep(exp.ExperimentSpec(), {
        "algorithm.name": ["dsgd", "mc_dsgt"],
        "channel.link_drop": [0.0, 0.2]})
    assert len(grid) == 4
    assert [(g.algorithm.name, g.channel.link_drop) for g in grid] == \
        [("dsgd", 0.0), ("dsgd", 0.2), ("mc_dsgt", 0.0), ("mc_dsgt", 0.2)]
    assert len({exp.spec_hash(g) for g in grid}) == 4


# ---------------------------------------------------------------------------
# Registry single-sourcing (the CLI derives its vocabularies)
# ---------------------------------------------------------------------------

def test_cli_choices_come_from_registries():
    actions = {a.dest: a for a in train.build_parser()._actions}
    assert list(actions["topology"].choices) == list(exp.TOPOLOGIES)
    assert list(actions["algo"].choices) == list(exp.ALGORITHMS)
    assert list(actions["local_opt"].choices) == sorted(exp.LOCAL_OPTS)
    assert list(actions["gossip_impl"].choices) == list(exp.GOSSIP_IMPLS)


def test_flag_map_paths_all_resolve():
    s = exp.ExperimentSpec()
    for dest, path in train.FLAG_TO_FIELD.items():
        exp.with_field(s, path, getattr(
            getattr(s, path.split(".")[0]), path.split(".")[1]))


def test_every_registered_topology_builds():
    from repro.exp import registry
    for kind in exp.TOPOLOGIES:
        # the sparse sampled family has no sensible default cohort size
        k = 4 if kind in registry.SPARSE_TOPOLOGIES else 0
        sched = exp.build_topology(exp.TopologySpec(kind=kind, sample_k=k),
                                   8, horizon=12, seed=0)
        assert sched.n == 8
        assert sched.period >= 1


def test_unknown_registry_values_error_with_choices():
    with pytest.raises(ValueError, match="topology.kind"):
        exp.build(exp.with_field(exp.ExperimentSpec(), "topology.kind", "x"))
    with pytest.raises(ValueError, match="algorithm.name"):
        exp.build(exp.with_field(exp.ExperimentSpec(), "algorithm.name", "x"))
    with pytest.raises(ValueError, match="gossip_impl"):
        exp.build(exp.with_field(exp.ExperimentSpec(),
                                 "run.gossip_impl", "x"))


# ---------------------------------------------------------------------------
# Config file round trip (flags override file)
# ---------------------------------------------------------------------------

def test_dump_config_then_config_roundtrip(tmp_path):
    spec = train.main(["--topology", "federated", "--algo", "local_sgd",
                       "--gossip-impl", "auto", "--dump-config"])
    assert isinstance(spec, exp.ExperimentSpec)
    assert spec.topology.kind == "federated"
    path = tmp_path / "fed.json"
    path.write_text(exp.to_json(spec))
    # file is the baseline; explicit flags override it
    merged = train.main(["--config", str(path), "--algo", "gt_local",
                         "--dump-config"])
    assert merged == exp.with_field(spec, "algorithm.name", "gt_local")
    # a manifest is accepted as a --config baseline too
    mpath = tmp_path / "fed.manifest.json"
    mpath.write_text(json.dumps(exp.resolved_manifest(spec)))
    assert train.main(["--config", str(mpath), "--dump-config"]) == spec


# ---------------------------------------------------------------------------
# Spec <-> CLI equivalence: bit-identical losses through both entries
# ---------------------------------------------------------------------------

_EQUIV = [
    # (algo, topology, gossip_impl, link_drop) — covers {mc_dsgt, local_sgd}
    # x {sun, waypoint-mobility + 20% drop} with dense AND auto paths
    ("mc_dsgt", "sun", "dense", 0.0),
    ("mc_dsgt", "waypoint-mobility", "auto", 0.2),
    ("local_sgd", "sun", "auto", 0.0),
    ("local_sgd", "waypoint-mobility", "dense", 0.2),
]


@pytest.mark.parametrize("algo,topo,impl,drop", _EQUIV)
def test_spec_cli_equivalence(algo, topo, impl, drop):
    flags = ["--arch", "qwen1.5-0.5b", "--preset", "reduced",
             "--steps", "2", "--nodes", "4", "--batch", "1", "--seq", "16",
             "--algo", algo, "--topology", topo, "--gossip-impl", impl]
    if drop:
        flags += ["--link-drop", str(drop)]
    spec = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16),
        algorithm=exp.AlgorithmSpec(name=algo),
        topology=exp.TopologySpec(kind=topo),
        channel=exp.ChannelSpec(link_drop=drop),
        run=exp.RunSpec(steps=2, nodes=4, gossip_impl=impl))
    cli_hist = train.main(flags)
    spec_hist = exp.run(spec, quiet=True).history
    assert [h["loss"] for h in cli_hist] == [h["loss"] for h in spec_hist]
    assert [h["consensus"] for h in cli_hist] == \
        [h["consensus"] for h in spec_hist]


# ---------------------------------------------------------------------------
# Reproducibility manifests
# ---------------------------------------------------------------------------

def _tiny_arch_spec(**run_kw):
    run_kw = {"steps": 2, "nodes": 4, **run_kw}
    return exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16),
        algorithm=exp.AlgorithmSpec(name="dsgd", gamma=0.05),
        run=exp.RunSpec(**run_kw))


def test_manifest_written_and_restore_mismatch_warns(tmp_path):
    ckpt = str(tmp_path / "ck.msgpack")
    spec = _tiny_arch_spec(checkpoint=ckpt)
    exp.run(spec, quiet=True)

    mpath = exp.manifest_path(ckpt)
    m = exp.load_manifest(mpath)
    assert m["spec_parsed"] == spec
    assert m["spec_hash"] == exp.spec_hash(spec)
    assert m["realized"]["weights_per_step"] == 1
    assert m["realized"]["seed"] == 0
    assert m["realized"]["period"] >= 1

    # same scenario, different step count: a legal continuation — no
    # spec-mismatch warning
    cont = _tiny_arch_spec(restore=ckpt, steps=1)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        exp.run(cont, quiet=True)
    assert not [w for w in caught if "manifest" in str(w.message)]

    # changed scenario field (gamma): restore proceeds but warns
    changed = exp.with_field(_tiny_arch_spec(restore=ckpt, steps=1),
                             "algorithm.gamma", 0.07)
    with pytest.warns(UserWarning, match="algorithm.gamma"):
        exp.run(changed, quiet=True)

    # resume IN PLACE (checkpoint == restore, the canonical continuation):
    # the ORIGINAL manifest must be compared before being overwritten
    inplace = exp.with_field(
        _tiny_arch_spec(restore=ckpt, checkpoint=ckpt, steps=1),
        "algorithm.gamma", 0.09)
    with pytest.warns(UserWarning, match="algorithm.gamma"):
        exp.run(inplace, quiet=True)
    assert exp.load_manifest(mpath)["spec_parsed"] == inplace  # now updated


def test_restore_warns_on_serve_field_change(tmp_path):
    """serve is scenario-defining (NOT in _NON_SCENARIO_SECTIONS): restoring
    a checkpoint under a different ServeSpec must warn like any other
    scenario drift — the manifest pins what the artifact was trained to
    serve."""
    ckpt = str(tmp_path / "ck.msgpack")
    exp.run(_tiny_arch_spec(checkpoint=ckpt), quiet=True)

    cont = exp.with_overrides(
        _tiny_arch_spec(restore=ckpt, steps=1),
        {"serve.requests": 2, "serve.batch": 2, "serve.prompt_len": 4,
         "serve.max_new": 2, "serve.dtype": "f32"})
    with pytest.warns(UserWarning, match="serve.requests"):
        res = exp.run(cont, quiet=True)
    # the warned run still serves: continuation + serve phase both happen
    assert res.serve is not None and res.serve.throughput["requests"] == 2


def test_telemetry_manifest_written(tmp_path):
    telem = str(tmp_path / "telem.json")
    spec = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=8, m=16),
        data=exp.DataSpec(batch=4),
        algorithm=exp.AlgorithmSpec(name="dsgd", gamma=0.3),
        topology=exp.TopologySpec(kind="geometric-mobility"),
        run=exp.RunSpec(steps=2, nodes=4, telemetry=telem))
    res = exp.run(spec)
    assert res.telemetry is not None and res.telemetry.history
    m = exp.load_manifest(exp.manifest_path(telem))
    assert m["spec_parsed"] == spec
    assert m["realized"]["plan_kinds"] is None  # dense impl: no plan


def test_corrupt_manifest_warns_not_raises(tmp_path):
    ckpt = str(tmp_path / "ck.msgpack")
    (tmp_path / "ck.msgpack.spec.json").write_text(
        json.dumps({"format": "repro.exp/manifest/v1", "spec": {"run": 3},
                    "spec_hash": "x", "realized": {}}))
    with pytest.warns(UserWarning, match="unreadable spec manifest"):
        assert exp.check_restore_spec(ckpt, exp.ExperimentSpec()) is None


def test_diff_specs_ignores_run_shape():
    a = exp.ExperimentSpec()
    b = exp.with_overrides(a, {"run.steps": 99, "run.checkpoint": "x",
                               "run.telemetry": "y"})
    assert exp.diff_specs(a, b) == []
    c = exp.with_overrides(a, {"topology.kind": "federated",
                               "run.nodes": 8})
    assert exp.diff_specs(a, c) == ["run.nodes", "topology.kind"]


# ---------------------------------------------------------------------------
# Logreg runtime guardrails + legacy surface
# ---------------------------------------------------------------------------

def test_logreg_rejects_arch_only_features():
    base = exp.ExperimentSpec(model=exp.ModelRef(kind="logreg"))
    with pytest.raises(ValueError, match="host runtime"):
        exp.build(exp.with_field(base, "run.gossip_impl", "pallas"))
    with pytest.raises(ValueError, match="checkpoint"):
        exp.build(exp.with_field(base, "run.checkpoint", "x"))


def test_legacy_make_weight_schedule_import():
    # the historical import site keeps working and delegates to the registry
    from repro.launch.train import make_weight_schedule
    sched = make_weight_schedule("sun", 8, 0.75)
    assert sched.n == 8
    assert sched.period >= 1


def test_example_spec_literals_roundtrip():
    """Every example's SPECS pool serializes strictly (running them is the
    CI spec-smoke job, repro.exp.validate)."""
    from repro.exp import validate as V
    seen = 0
    for example, name, spec in V.iter_example_specs("examples"):
        assert exp.from_json(exp.to_json(spec)) == spec, (example, name)
        seen += 1
    assert seen >= 6  # quickstart x3, federated x3, wireless x2, figure2 x1
