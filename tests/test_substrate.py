"""Substrate tests: data pipeline, checkpointing, optimizers, attention
variants, MoE invariants, recurrence state continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import configs
from repro.data import TokenStream, logreg_dataset, logreg_loss_and_grad


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_distinct():
    s = TokenStream(vocab_size=100, n_nodes=4, rounds=2, batch=2, seq=16)
    b1, b2 = s.batch_at(3), s.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # microbatches differ across rounds (independent oracle draws)
    assert not np.array_equal(b1["tokens"][:, 0], b1["tokens"][:, 1])


def test_token_stream_modalities():
    cfgv = configs.get("internvl2-1b").reduced()
    from repro.data import token_stream_for
    sv = token_stream_for(cfgv, 2, 1, 2, 24)
    b = sv.batch_at(0)
    assert b["prefix_embeds"].shape == (2, 1, 2, cfgv.frontend_tokens, cfgv.d_model)
    assert b["tokens"].shape == (2, 1, 2, 24 - cfgv.frontend_tokens)
    cfga = configs.get("whisper-tiny").reduced()
    sa = token_stream_for(cfga, 2, 1, 2, 16)
    b = sa.batch_at(0)
    assert b["frames"].shape == (2, 1, 2, cfga.encoder_seq, cfga.d_model)


def test_logreg_heterogeneous_partition():
    H, y = logreg_dataset(8, 100, 16, positive_frac=0.8, seed=0)
    pos_frac_first = float((y[0] > 0).mean())
    pos_frac_last = float((y[-1] > 0).mean())
    assert abs(pos_frac_first - 0.8) < 0.05
    assert abs(pos_frac_last - 0.2) < 0.05


def test_logreg_oracle_unbiased():
    """Minibatch oracle expectation == full gradient (Assumption 2)."""
    H, y = logreg_dataset(4, 64, 8, seed=2)
    _, full_grad, stoch, _, _ = logreg_loss_and_grad(rho=0.05)
    xs = jnp.zeros((4, 8))
    g_full = full_grad(xs, H, y)
    samples = jnp.stack([stoch(xs, H, y, jax.random.key(s), 16)
                         for s in range(300)])
    np.testing.assert_allclose(np.asarray(samples.mean(0)),
                               np.asarray(g_full), atol=0.05)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_adam_reduces_quadratic():
    from repro.optim import adam
    opt = adam()
    x = jnp.array([5.0, -3.0])
    s = opt.init(x)
    for _ in range(300):
        g = 2 * x
        upd, s = opt.update(g, s)
        x = x - 0.1 * upd
    assert float(jnp.abs(x).max()) < 0.05


def test_momentum_matches_manual():
    from repro.optim import momentum
    opt = momentum(0.9)
    s = opt.init(jnp.zeros(3))
    g = jnp.ones(3)
    u1, s = opt.update(g, s)
    u2, s = opt.update(g, s)
    np.testing.assert_allclose(np.asarray(u2), 1.9 * np.ones(3), rtol=1e-6)


# ---------------------------------------------------------------------------
# Attention variants
# ---------------------------------------------------------------------------

def test_sliding_block_matches_masked_full():
    """attend_sliding_block == attend_full with a window mask (exactness of
    the sub-quadratic path used by long_500k)."""
    from repro.models import attention as attn
    ks = jax.random.split(jax.random.key(0), 3)
    B, S, J, G, hd, w = 1, 96, 2, 2, 32, 32
    q = jax.random.normal(ks[0], (B, S, J, G, hd))
    k = jax.random.normal(ks[1], (B, S, J, hd))
    v = jax.random.normal(ks[2], (B, S, J, hd))
    pos = jnp.arange(S)
    a = attn.attend_sliding_block(q, k, v, pos, window=w)
    b = attn.attend_full(q, k, v, pos, pos, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(s_mult=st.integers(2, 5), w_div=st.sampled_from([16, 32]),
       seed=st.integers(0, 20))
def test_property_sliding_block_any_shape(s_mult, w_div, seed):
    from repro.models import attention as attn
    S, w = 16 * s_mult, w_div
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, S, 1, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 1, 16))
    v = jax.random.normal(ks[2], (1, S, 1, 16))
    pos = jnp.arange(S)
    a = attn.attend_sliding_block(q, k, v, pos, window=w)
    b = attn.attend_full(q, k, v, pos, pos, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_chunked_attention_matches_unchunked():
    from repro.models import attention as attn
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 100, 1, 2, 16))  # non-divisible length
    k = jax.random.normal(ks[1], (1, 100, 1, 16))
    v = jax.random.normal(ks[2], (1, 100, 1, 16))
    pos = jnp.arange(100)
    a = attn.attend_full(q, k, v, pos, pos, causal=True, q_chunk=32)
    b = attn.attend_full(q, k, v, pos, pos, causal=True, q_chunk=1000)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

def test_moe_dropless_routing_weights_sum():
    """With ample capacity, combine weights per token sum to 1 and the layer
    is permutation-consistent."""
    from repro.models import moe as moelib
    cfg = configs.get("granite-moe-3b-a800m").reduced()
    p = moelib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, aux = moelib.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0.0
    # token order permutation of the batch only permutes outputs
    perm = jnp.array([1, 0])
    out_p, _ = moelib.apply_moe(p, x[perm], cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out[perm]),
                               atol=2e-5)


def test_moe_capacity_drops_degrade_gracefully():
    from repro.models import moe as moelib
    cfg = configs.get("granite-moe-3b-a800m").reduced()
    p = moelib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model))
    out_full, _ = moelib.apply_moe(p, x, cfg, capacity_factor=64.0)
    out_tight, _ = moelib.apply_moe(p, x, cfg, capacity_factor=0.25)
    # tight capacity drops tokens (outputs zeroed) but never NaNs
    assert not bool(jnp.isnan(out_tight).any())
    assert float(jnp.abs(out_tight).sum()) < float(jnp.abs(out_full).sum())


# ---------------------------------------------------------------------------
# Recurrence state continuity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["mamba", "rglru"])
def test_recurrence_segment_continuity(family):
    """Running a sequence in two halves with carried state == one pass."""
    if family == "mamba":
        from repro.models import ssm as mod
        cfg = configs.get("falcon-mamba-7b").reduced()
        p = mod.init_mamba(jax.random.key(0), cfg, jnp.float32)
        fwd = lambda x, st: mod.mamba_forward(p, x, cfg, state=st)
        state0 = mod.init_mamba_cache(cfg, 1, jnp.float32)
    else:
        from repro.models import rglru as mod
        cfg = configs.get("recurrentgemma-2b").reduced()
        p = mod.init_rglru(jax.random.key(0), cfg, jnp.float32)
        fwd = lambda x, st: mod.rglru_forward(p, x, cfg, state=st)
        state0 = mod.init_rglru_cache(cfg, 1, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model))
    y_full, _ = fwd(x, state0)
    y1, st = fwd(x[:, :16], state0)
    y2, _ = fwd(x[:, 16:], st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=3e-4)
