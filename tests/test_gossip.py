"""Unit + property tests for gossip weight matrices (Assumption 3, Thm 3, eq. 21)."""

import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import gossip, topology as topo


@pytest.mark.parametrize("n,beta", [(8, 0.5), (16, 0.75), (16, 1 - 1 / 16),
                                    (32, 0.9), (12, 0.25), (8, 0.0)])
def test_theorem3_matrices_assumption3(n, beta):
    sched = gossip.theorem3_weight_schedule(n, beta)
    graphs = topo.sun_shaped_schedule(n, beta)
    for t in range(sched.period):
        gossip.check_assumption3(sched(t), graphs(t), beta)


@pytest.mark.parametrize("n,beta", [(16, 0.5), (16, 0.9), (32, 0.75)])
def test_theorem3_beta_is_tight(n, beta):
    """Theorem 3 proof: ||W - 11^T/n||_2 is exactly beta for the construction."""
    sched = gossip.theorem3_weight_schedule(n, beta)
    for t in range(sched.period):
        assert abs(gossip.mixing_beta(sched(t)) - beta) < 1e-9


def test_contraction_eq21():
    """||prod W^t - 11^T/n||_2 <= beta^rounds (eq. 21)."""
    n, beta = 16, 0.75
    sched = gossip.theorem3_weight_schedule(n, beta)
    for rounds in [1, 2, 4, 8]:
        c = gossip.consensus_contraction(sched, rounds)
        assert c <= beta ** rounds + 1e-9, (rounds, c, beta ** rounds)


def test_laplacian_rule_common_topologies():
    """Remark 5: Laplacian-rule matrices of common graphs satisfy Assumption 3
    with beta <= 1 - 1/n for large enough n."""
    for n, make in [(16, topo.ring_graph), (16, topo.complete_graph),
                    (16, topo.static_exponential_graph),
                    (16, lambda n: topo.star_graph(n, 0))]:
        adj = make(n)
        W = gossip.laplacian_rule(adj)
        gossip.check_assumption3(W, adj)


def test_metropolis_weights():
    adj = topo.erdos_renyi_graph(12, 0.4, seed=3)
    W = gossip.metropolis_weights(adj)
    gossip.check_assumption3(W, adj)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 24), seed=st.integers(0, 100),
       rounds=st.integers(1, 6))
def test_property_contraction_any_schedule(n, seed, rounds):
    """Property: for any ER-graph schedule, the multi-consensus product
    contracts at least as fast as max-beta^rounds (eq. 21)."""
    rng = np.random.default_rng(seed)
    mats = []
    for t in range(rounds):
        adj = topo.erdos_renyi_graph(n, 0.5, seed=int(rng.integers(1e6)))
        mats.append(gossip.laplacian_rule(adj))
    sched = gossip.WeightSchedule(tuple(mats))
    beta = sched.beta
    c = gossip.consensus_contraction(sched, rounds)
    assert c <= beta ** rounds + 1e-7


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 32), beta_frac=st.floats(0.0, 1.0))
def test_property_theorem3_any_beta(n, beta_frac):
    """Property: the Theorem 3 construction is valid for any beta in
    [0, 1-1/n]."""
    beta = beta_frac * (1 - 1 / n)
    sched = gossip.theorem3_weight_schedule(n, beta)
    for t in range(sched.period):
        W = sched(t)
        gossip.check_assumption3(W, beta=beta + 1e-9)


def test_multi_consensus_matches_matrix_product():
    n = 8
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    rng = np.random.default_rng(0)
    z = rng.normal(size=(n, 5))
    out = gossip.multi_consensus(z, sched, 2, 7)
    P = np.eye(n)
    for t in range(2, 7):
        P = sched(t) @ P
    assert np.allclose(out, P @ z, atol=1e-12)


def test_mean_preservation():
    """Double stochasticity => gossip preserves the node-mean exactly."""
    n = 16
    sched = gossip.theorem3_weight_schedule(n, 0.8)
    rng = np.random.default_rng(1)
    z = rng.normal(size=(n, 7))
    out = gossip.multi_consensus(z, sched, 0, 11)
    assert np.allclose(out.mean(0), z.mean(0), atol=1e-12)
