"""Behavioural tests for DSGD / DSGT / MC-DSGT (paper Alg. 1, Table 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import gossip



def quadratic_problem(n=8, d=5, hetero=2.0, seed=0):
    """f_i(x) = 0.5 ||x - c_i||^2 with heterogeneous centers; the global
    optimum is the centroid of the c_i."""
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)) * hetero)

    def grad_fn(xs, key):
        noise = jax.random.normal(key, xs.shape) * 0.0
        return xs - centers + noise

    def noisy_grad_fn(sigma):
        def g(xs, key):
            return xs - centers + sigma * jax.random.normal(key, xs.shape)
        return g

    xstar = centers.mean(0)
    return centers, grad_fn, noisy_grad_fn, xstar


def _run(algo, x0, grad_fn, sched, steps, seed=0):
    state, _ = alg.run(algo, x0, grad_fn, sched, steps, jax.random.key(seed))
    return state


def test_mix_preserves_mean():
    n, d = 8, 3
    sched = gossip.theorem3_weight_schedule(n, 0.7)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)))
    W = jnp.asarray(sched(0))
    out = alg.mix(W, {"p": x})["p"]
    np.testing.assert_allclose(out.mean(0), x.mean(0), atol=1e-6)


def test_dsgt_exact_convergence_deterministic():
    """With sigma = 0, DSGT converges to the exact consensus optimum even
    under heterogeneous data (gradient tracking removes the DSGD bias)."""
    n, d = 8, 5
    centers, grad_fn, _, xstar = quadratic_problem(n, d)
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    x0 = jnp.zeros((n, d))
    algo = alg.dsgt(gamma=0.4)
    state = _run(algo, x0, grad_fn, sched, 150)
    xbar = state.x.mean(0)
    np.testing.assert_allclose(np.asarray(xbar), np.asarray(xstar), atol=1e-4)
    # consensus: all copies agree
    assert float(jnp.abs(state.x - xbar[None]).max()) < 1e-3


def test_dsgd_has_heterogeneity_bias_dsgt_does_not():
    """Table 1: DSGD's rate carries a data-heterogeneity term; with
    heterogeneous curvature and a poorly connected graph at constant step
    size, DSGD's mean iterate stalls away from the optimum while gradient
    tracking (DSGT) converges exactly."""
    n, d = 16, 4
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 5.0)
    hess = jnp.asarray(rng.uniform(0.2, 1.8, size=(n, d)))  # diagonal A_i

    def grad_fn(xs, key):
        return hess * (xs - centers)

    # global optimum of (1/n) sum 0.5 (x-c_i)^T A_i (x-c_i)
    xstar = (hess * centers).mean(0) / hess.mean(0)
    sched = gossip.theorem3_weight_schedule(n, 0.9)
    x0 = jnp.zeros((n, d))
    s_dsgd = _run(alg.dsgd(0.4), x0, grad_fn, sched, 150)
    s_dsgt = _run(alg.dsgt(0.4), x0, grad_fn, sched, 120)
    err_dsgd = float(jnp.linalg.norm(s_dsgd.x.mean(0) - xstar))
    err_dsgt = float(jnp.linalg.norm(s_dsgt.x.mean(0) - xstar))
    assert err_dsgt < 1e-3
    assert err_dsgd > 10 * max(err_dsgt, 1e-6)


def test_mc_dsgt_reduces_consensus_error_vs_dsgt():
    """Multi-consensus shrinks rho = beta^R: on a badly connected schedule,
    MC-DSGT's consensus error after equal oracle budget is far smaller."""
    n, d = 16, 4
    centers, grad_fn, noisy, xstar = quadratic_problem(n, d, hetero=5.0)
    beta = 1 - 1 / n  # worst connectivity allowed by Theorem 3
    sched = gossip.theorem3_weight_schedule(n, beta)
    x0 = jnp.zeros((n, d))
    R = 4
    # equal budget T = K * weights_per_step
    s_mc = _run(alg.mc_dsgt(0.3, R=R), x0, grad_fn, sched, 30)
    s_1 = _run(alg.dsgt(0.3), x0, grad_fn, sched, 30 * R)
    def consensus_err(s):
        xbar = s.x.mean(0, keepdims=True)
        return float(jnp.linalg.norm(s.x - xbar))
    assert consensus_err(s_mc) < consensus_err(s_1) + 1e-6
    err_mc = float(jnp.linalg.norm(s_mc.x.mean(0) - xstar))
    assert err_mc < 1e-2


def test_mc_dsgt_complete_graph_r1_equals_centralized_sgd():
    """Sanity: on the complete graph (beta = 0) with R = 1 and sigma = 0,
    MC-DSGT's mean iterate is exactly centralized gradient descent on f."""
    n, d = 8, 3
    centers, grad_fn, _, xstar = quadratic_problem(n, d)
    W = jnp.ones((n, n)) / n
    sched = gossip.WeightSchedule((np.ones((n, n)) / n,))
    x0 = jnp.zeros((n, d))
    gamma = 0.4
    algo = alg.mc_dsgt(gamma, R=1)
    state = algo.init(x0)
    state = alg.warm_start(algo, state, grad_fn, jax.random.key(0))
    # centralized reference: x_{k+1} = x_k - gamma * mean_i grad_i(x_k)
    xc = jnp.zeros(d)
    for k in range(10):
        Ws = jnp.asarray(sched.stacked(0, 2))
        state = algo.step(state, grad_fn, Ws, jax.random.key(k + 1))
        xc = xc - gamma * (xc - xstar)
        np.testing.assert_allclose(np.asarray(state.x[0]), np.asarray(xc),
                                   atol=1e-5)


def test_gradient_accumulation_variance_reduction():
    """E||g_acc - grad||^2 <= sigma^2 / R (eq. 19)."""
    n, d, sigma, R = 4, 6, 1.0, 8
    centers, _, noisy, _ = quadratic_problem(n, d)
    grad_fn = noisy(sigma)
    xs = jnp.zeros((n, d))
    true = xs - centers
    samples = []
    for s in range(80):
        g = alg._accumulate(grad_fn, xs, jax.random.key(s), R)
        samples.append(np.asarray(g - true))
    var = np.mean([np.sum(s ** 2, axis=-1).mean() for s in samples])
    # per-node variance of the accumulated gradient ~= d * sigma^2 / R
    assert var < 1.5 * d * sigma ** 2 / R
    assert var > 0.5 * d * sigma ** 2 / R


def test_time_varying_schedule_consumed_in_order():
    """MC-DSGT consumes rounds [2kR, (2k+1)R) for x and [(2k+1)R, (2k+2)R)
    for h.  The driver stages the schedule ONCE (no per-step re-stacking)
    and gathers each step's window by index — the final state must equal a
    manual loop handing the stacked windows over in schedule order."""
    n, d, R = 6, 2, 2
    steps = 3
    seen = []

    class RecordingSchedule:
        def __init__(self, inner):
            self.inner = inner
            self.period = inner.period
        def stacked(self, t0, rounds, dtype=np.float32):
            seen.append((t0, rounds))
            return self.inner.stacked(t0, rounds, dtype)

    sched = gossip.theorem3_weight_schedule(n, 0.5)
    rec = RecordingSchedule(sched)
    centers, grad_fn, _, _ = quadratic_problem(n, d)
    algo = alg.mc_dsgt(0.1, R=R)
    state, _ = alg.run(algo, jnp.zeros((n, d)), grad_fn, rec, steps,
                       jax.random.key(0))
    # staged exactly once, one period (or the whole run if shorter)
    assert seen == [(0, min(sched.period, steps * 4))]

    # reference: hand the (2kR, 4)-windows over step by step
    key = jax.random.key(0)
    key, k0 = jax.random.split(key)
    ref = alg.warm_start(algo, algo.init(jnp.zeros((n, d))), grad_fn, k0)
    for k in range(steps):
        key, sub = jax.random.split(key)
        ref = algo.step(ref, grad_fn, jnp.asarray(sched.stacked(4 * k, 4)),
                        sub)
    np.testing.assert_allclose(np.asarray(state.x), np.asarray(ref.x),
                               rtol=1e-5, atol=1e-6)


def test_d2_removes_heterogeneity_bias():
    """D^2 [35] (extra baseline): converges exactly under heterogeneous
    curvature where DSGD stalls, like DSGT."""
    n, d = 16, 4
    rng = np.random.default_rng(3)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 5.0)
    hess = jnp.asarray(rng.uniform(0.3, 1.2, size=(n, d)))

    def grad_fn(xs, key):
        return hess * (xs - centers)

    xstar = (hess * centers).mean(0) / hess.mean(0)
    sched = gossip.theorem3_weight_schedule(n, 0.75)
    s_d2 = _run(alg.d2(0.3), jnp.zeros((n, d)), grad_fn, sched, 250)
    err = float(jnp.linalg.norm(s_d2.x.mean(0) - xstar))
    assert err < 1e-3, err
