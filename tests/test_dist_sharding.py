"""Fast in-process unit tests for `repro.dist.sharding` and the fused
Pallas gossip path.

No real device mesh is needed: the sharding rules consult only
``mesh.axis_names`` and ``mesh.shape``, so a mocked mesh object drives
every branch (stacked nodes, audio cache, hierarchical / multi-pod axes,
divisibility fallbacks) without the 8-device subprocess harness."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import algorithms as alg, gossip
from repro.dist import collectives as coll
from repro.dist import sharding as shd
from repro.models import build


def mock_mesh(**axes):
    return types.SimpleNamespace(axis_names=tuple(axes), shape=dict(axes))


MESH_42 = mock_mesh(data=4, model=2)
MESH_HIER = mock_mesh(node=2, fsdp=2, model=2)
MESH_POD = mock_mesh(pod=2, data=16, model=16)


def _shapes(cfg, dtype=jnp.float32):
    model = build(cfg)
    return model, jax.eval_shape(lambda: model.init(jax.random.key(0), dtype))


# ---------------------------------------------------------------------------
# axis helpers
# ---------------------------------------------------------------------------

def test_n_nodes_per_mesh_flavour():
    assert shd.n_nodes(MESH_42) == 4
    assert shd.n_nodes(MESH_HIER) == 2
    assert shd.n_nodes(MESH_POD) == 32
    assert coll.tp_axes(MESH_42) == ("model",)
    assert coll.tp_axes(MESH_HIER) == ("fsdp", "model")
    assert coll.node_axes(MESH_POD) == ("pod", "data")


# ---------------------------------------------------------------------------
# param_specs: dense transformer
# ---------------------------------------------------------------------------

def test_param_specs_dense_transformer():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    _, params = _shapes(cfg)
    specs = shd.param_specs(params, cfg, MESH_42)
    # embedding (V, D): vocab over the tensor-parallel axis
    assert specs["embed"]["embedding"] == P("model", None)
    assert specs["final_norm"]["scale"] == P(None)
    unit = specs["units"]["0_attn"]
    # wq (units, D, H, hd): heads divide the model axis
    assert unit["attn"]["wq"] == P(None, None, "model", None)
    assert unit["attn"]["wo"] == P(None, "model", None, None)
    # mlp wi (units, D, F): generic rule shards the last dim
    assert unit["mlp"]["wi"] == P(None, None, "model")
    assert unit["ln1"]["scale"] == P(None, None)


def test_param_specs_stacked_nodes_prepends_node_axis():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model, params = _shapes(cfg)

    def stack(n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), params)

    specs = shd.param_specs(stack(4), cfg, MESH_42, stacked_nodes=True)
    assert specs["embed"]["embedding"] == P("data", "model", None)
    assert specs["units"]["0_attn"]["attn"]["wq"] == \
        P("data", None, None, "model", None)
    # hierarchical mesh: node axis + combined fsdp x model group
    specs2 = shd.param_specs(stack(2), cfg, MESH_HIER, stacked_nodes=True)
    assert specs2["units"]["0_attn"]["attn"]["wq"] == \
        P("node", None, None, ("fsdp", "model"), None)


def test_param_specs_divisibility_fallbacks():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    _, params = _shapes(cfg)
    # model=3 divides neither vocab (512) nor d_ff (512) -> replicate
    specs = shd.param_specs(params, cfg, mock_mesh(data=2, model=3))
    assert specs["embed"]["embedding"] == P(None, None)
    assert specs["units"]["0_attn"]["mlp"]["wi"] == P(None, None, None)
    # model=8 exceeds the 4 heads -> attn_shard_fallback shards head_dim
    specs8 = shd.param_specs(params, cfg, mock_mesh(data=1, model=8))
    assert specs8["units"]["0_attn"]["attn"]["wq"] == \
        P(None, None, None, "model")


def test_param_specs_moe_expert_parallel():
    cfg = configs.get("granite-moe-3b-a800m").reduced()
    _, params = _shapes(cfg)
    specs = shd.param_specs(params, cfg, MESH_42)
    moe = specs["units"]["0_moe"]["moe"]
    # wi (units, E, D, F): E=4 divides model=2 -> expert-parallel
    assert moe["wi"] == P(None, "model", None, None)
    assert moe["router"] == P(None, None, "model")
    # E=4 does not divide model=8 -> falls back to the expert FFN dim
    specs8 = shd.param_specs(params, cfg, mock_mesh(data=1, model=8))
    assert specs8["units"]["0_moe"]["moe"]["wi"] == P(None, None, None, "model")


# ---------------------------------------------------------------------------
# param_specs: caches (including the audio_cache branch)
# ---------------------------------------------------------------------------

def test_cache_specs_transformer():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(8, 64, jnp.float32))
    specs = shd.param_specs(cache, cfg, MESH_42)
    # k (units, B, C, KV, hd): batch over data, KV heads over model
    assert specs["units"]["0_attn"]["k"] == P(None, "data", None, "model", None)
    assert specs["units"]["0_attn"]["kpos"] == P(None, None)


def test_cache_specs_audio():
    cfg = configs.get("whisper-tiny").reduced()
    model = build(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(8, 64, jnp.float32))
    specs = shd.param_specs(cache, cfg, MESH_42, audio_cache=True)
    # every leaf is stacked over a leading (replicated) layer axis
    assert specs["self"]["k"] == P(None, "data", None, "model", None)
    assert specs["cross_k"] == P(None, "data", None, "model", None)
    assert specs["cross_kpos"] == P(None, None)


# ---------------------------------------------------------------------------
# batch_specs
# ---------------------------------------------------------------------------

def test_batch_specs():
    tok = jax.ShapeDtypeStruct((4, 2, 2, 32), jnp.int32)
    specs = shd.batch_specs({"tokens": tok}, MESH_42, stacked_nodes=True)
    assert specs["tokens"] == P("data", None, None, None)
    # serve batch: global batch over data
    tok2 = jax.ShapeDtypeStruct((32, 128), jnp.int32)
    assert shd.batch_specs({"t": tok2}, MESH_42)["t"] == P("data", None)
    # multi-pod: node dimension spans (pod, data)
    tok3 = jax.ShapeDtypeStruct((32, 2, 4, 128), jnp.int32)
    specs3 = shd.batch_specs({"tokens": tok3}, MESH_POD, stacked_nodes=True)
    assert specs3["tokens"] == P(("pod", "data"), None, None, None)
    # non-divisible leading dim -> replicated
    tok4 = jax.ShapeDtypeStruct((3, 128), jnp.int32)
    assert shd.batch_specs({"t": tok4}, MESH_42)["t"] == P(None, None)


# ---------------------------------------------------------------------------
# fused Pallas multi-consensus (interpret mode)
# ---------------------------------------------------------------------------

def test_fused_multi_consensus_matches_dense():
    n, R = 8, 3
    sched = gossip.theorem3_weight_schedule(n, 0.75)
    Ws = jnp.asarray(sched.stacked(0, R))
    key = jax.random.key(0)
    tree = {
        "a": jax.random.normal(key, (n, 5, 7)),
        "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (n, 33)),
              "d": jax.random.normal(jax.random.fold_in(key, 2),
                                     (n, 4)).astype(jnp.bfloat16)},
    }
    want = alg.multi_consensus(Ws, tree)
    got = coll.fused_multi_consensus(Ws, tree, block_d=16, interpret=True)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert w.dtype == g.dtype
        np.testing.assert_allclose(np.asarray(w, np.float32),
                                   np.asarray(g, np.float32),
                                   atol=2e-2 if w.dtype == jnp.bfloat16
                                   else 1e-5)


def test_flatten_unflatten_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(4, 3),
            "b": jnp.ones((4, 2, 2), jnp.bfloat16)}
    mat, meta = coll.flatten_stacked(tree)
    assert mat.shape == (4, 3 + 4)
    back = coll.unflatten_stacked(mat, meta)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
