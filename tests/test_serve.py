"""repro.serve: routing policies, traffic synthesis, continuous-batching
parity against per-request sequential decode, obs events, and the
exp.run train->serve integration (personalized plan lowering included)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, exp
from repro.models import build as build_model
from repro.serve import (Request, ServeResult, route_user, serve_fleet,
                         synth_requests)


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    keys = jax.random.split(jax.random.key(0), 2)
    fleet = jax.vmap(lambda k: model.init(k, jnp.float32))(keys)
    return cfg, model, fleet


def _serve_spec(**kw):
    kw = {"requests": 5, "batch": 2, "max_new": 4, "prompt_len": 6,
          "dtype": "f32", **kw}
    return exp.ServeSpec(**kw)


# ---------------------------------------------------------------------------
# Routing + traffic
# ---------------------------------------------------------------------------

def test_route_user_policies():
    # round-robin ignores the user entirely
    assert [route_user(7, rid, 4, "round-robin") for rid in range(6)] == \
        [0, 1, 2, 3, 0, 1]
    # user-affinity ignores the rid entirely: one user -> one node, stable
    nodes = {route_user(3, rid, 4, "user-affinity") for rid in range(6)}
    assert len(nodes) == 1 and nodes.pop() in range(4)
    with pytest.raises(ValueError, match="unknown routing"):
        route_user(0, 0, 4, "sticky")
    with pytest.raises(ValueError, match="fleet"):
        route_user(0, 0, 0, "round-robin")


def test_synth_requests_deterministic():
    sv = _serve_spec(requests=12, routing="user-affinity", seed=3)
    a = synth_requests(sv, fleet=4, vocab=64)
    b = synth_requests(sv, fleet=4, vocab=64)
    assert len(a) == 12
    for ra, rb in zip(a, b):
        assert (ra.rid, ra.user, ra.node) == (rb.rid, rb.user, rb.node)
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.prompt.shape == (sv.prompt_len,)
        assert 0 <= ra.node < 4
        assert ra.node == route_user(ra.user, ra.rid, 4, "user-affinity")
    # a different traffic seed draws different prompts
    c = synth_requests(_serve_spec(requests=12, seed=4), fleet=4, vocab=64)
    assert any(not np.array_equal(ra.prompt, rc.prompt)
               for ra, rc in zip(a, c))


# ---------------------------------------------------------------------------
# Continuous batching == sequential decode, per request
# ---------------------------------------------------------------------------

def _sequential_decode(model, p_node, req, sv):
    """The oracle: serve ONE request alone, batch-1 prefill + decode."""
    cache = model.init_cache(1, sv.prompt_len + sv.max_new, jnp.float32)
    logits, cache = model.prefill(
        p_node, {"tokens": jnp.asarray(req.prompt)[None]}, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = sv.prompt_len
    while len(toks) < sv.max_new:
        cur = jnp.full((1, 1), toks[-1], jnp.int32)
        logits, cache = model.decode_step(p_node, cur, cache, jnp.int32(pos))
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return toks


def test_continuous_batching_matches_sequential(tiny):
    """Slots at different depths, params, and admit times batch together —
    and every request's tokens must equal serving it alone."""
    cfg, model, fleet = tiny
    sv = _serve_spec(requests=5, batch=2)
    reqs = synth_requests(sv, fleet=2, vocab=cfg.vocab_size)
    res = serve_fleet(model, fleet, sv, requests=reqs)
    assert isinstance(res, ServeResult)
    assert [c["rid"] for c in res.completed] == list(range(5))
    for rec, req in zip(res.completed, reqs):
        assert rec["node"] == req.node and rec["user"] == req.user
        p_node = jax.tree.map(lambda l: l[req.node], fleet)
        assert rec["tokens"] == _sequential_decode(model, p_node, req, sv), \
            f"rid {req.rid} diverged from its solo decode"
        assert len(rec["tokens"]) == sv.max_new


def test_serve_emits_obs_events_and_throughput(tiny):
    cfg, model, fleet = tiny

    class Sink:
        events = []

        def emit(self, e):
            self.events.append(e)

    sv = _serve_spec(requests=4, batch=3)
    res = serve_fleet(model, fleet, sv, obs=Sink())
    kinds = [e["event"] for e in Sink.events]
    assert kinds.count("serve_request") == 4
    assert kinds[-1] == "serve_summary"
    json.dumps(Sink.events)  # every event must be JSONL-serializable
    tp = res.throughput
    assert tp["requests"] == 4 and tp["fleet"] == 2 and tp["batch"] == 3
    for key in ("prefill_tok_s", "decode_tok_s", "requests_per_s",
                "latency_p50_ms", "latency_p95_ms"):
        assert tp[key] > 0
    assert tp["latency_p95_ms"] >= tp["latency_p50_ms"]


def test_serve_rejects_unknown_dtype(tiny):
    cfg, model, fleet = tiny
    with pytest.raises(ValueError, match="dtype"):
        serve_fleet(model, fleet, _serve_spec(dtype="fp4"))


# ---------------------------------------------------------------------------
# exp.run integration: train a personalized fleet, then serve it
# ---------------------------------------------------------------------------

def test_exp_run_serve_phase_personalized():
    spec = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16, active_vocab=16,
                          hetero_alpha=0.5),
        algorithm=exp.AlgorithmSpec(name="personalized", gamma=0.1, tau=4.0),
        run=exp.RunSpec(steps=2, nodes=4, gossip_impl="auto"),
        serve=exp.ServeSpec(requests=4, batch=2, prompt_len=4, max_new=2,
                            dtype="f32"))
    res = exp.run(spec, quiet=True)
    assert isinstance(res.serve, ServeResult)
    assert res.serve.fleet == 4
    assert res.serve.throughput["requests"] == 4
    # the personalized rule lowers through a REAL plan kind — per-node
    # weight rows staged as-is, never the dense fallback
    plan = res.built.plan
    assert set(plan.kinds) == {"personalized"}
    assert all(rd.fallback_reason is None for rd in plan.rounds)
    assert res.built.realized["serve"]["requests"] == 4
    # the trained fleet is genuinely per-node: node copies differ
    leaves = jax.tree.leaves(res.state.x)
    assert any(float(jnp.abs(l[0] - l[1]).max()) > 0 for l in leaves)


def test_serve_fleet_slice_field():
    spec = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16, active_vocab=16),
        algorithm=exp.AlgorithmSpec(name="dsgd", gamma=0.05),
        run=exp.RunSpec(steps=1, nodes=4),
        serve=exp.ServeSpec(requests=3, batch=2, prompt_len=4, max_new=2,
                            fleet=2, dtype="f32"))
    res = exp.run(spec, quiet=True)
    assert res.serve.fleet == 2
    assert all(c["node"] < 2 for c in res.serve.completed)


def test_validate_serve_guards():
    base = exp.ExperimentSpec(serve=exp.ServeSpec(requests=4))
    with pytest.raises(ValueError, match="arch"):
        exp.build(exp.with_field(base, "model.kind", "logreg"))
    with pytest.raises(ValueError, match="routing"):
        exp.build(exp.with_field(base, "serve.routing", "sticky"))
    with pytest.raises(ValueError, match="dtype"):
        exp.build(exp.with_field(base, "serve.dtype", "fp4"))
    with pytest.raises(ValueError, match="fleet"):
        exp.build(exp.with_field(base, "serve.fleet", 99))
    with pytest.raises(ValueError, match="requests"):
        exp.build(exp.with_field(base, "serve.requests", -1))
    # requests=0 disables the phase entirely: logreg + serve defaults builds
    off = exp.with_overrides(base, {"serve.requests": 0,
                                    "model.kind": "logreg"})
    assert not off.serve.enabled
    exp.build(off)


def test_serve_spec_round_trips():
    spec = exp.ExperimentSpec(
        serve=exp.ServeSpec(requests=8, batch=4, routing="round-robin"))
    again = exp.from_json(exp.to_json(spec))
    assert again == spec
    assert again.serve.enabled
    # spec_hash must see the serve section (manifest regeneration contract)
    assert exp.spec_hash(spec) != exp.spec_hash(exp.ExperimentSpec())
