"""Async overlapped gossip: stale-window delay, comm_interval gating,
hierarchical two-level lowering, and the overlap proof.

The contract under test, layer by layer:

1. ``delay=0`` is BIT-EXACT to the synchronous path on every runtime
   (host einsum, host auto-plan, dist dense, dist auto) — the feature
   must be free when off.
2. ``delay=d`` matches a hand-rolled stale-window recursion (the tests
   are the oracle), and dense == auto stay bit-identical under delay.
3. The overlap claim is *proved* from the jaxpr: with ``delay>0`` no
   ``obs_mix`` equation transitively consumes an ``obs_grad`` output
   (:func:`repro.obs.overlap_report`), so XLA may run the collectives
   concurrently with the grad; at ``delay=0`` the same report shows the
   serialization.
4. Doubly-stochastic stale windows preserve the tracker mean invariant
   (mean h == mean g_prev survives the delayed correction).
5. ``comm_interval=k`` skips the mix (pure local update) on steps with
   ``k % interval != 0`` while the delay buffers still advance.
6. Rounds that factor as B ⊗ J_p across pod boundaries take the
   two-level lowering: planner detection, exact dense reconstruction,
   mixer parity, and the hierarchical topology end to end.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exp
from repro.core import algorithms as alg, engine, gossip
from repro.dist import steps as dsteps
from repro.obs import overlap_report

from test_engine import ToyModel, _toy_batch


def _tree_err(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _assert_bit_exact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def _quadratic(n=8, d=5, hetero=2.0, seed=0):
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)) * hetero)
    return centers, lambda xs, key: xs - centers


# ---------------------------------------------------------------------------
# 1. delay=0 == synchronous, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,R", [("dsgd", 1), ("mc_dsgt", 2),
                                    ("gt_local", 1)])
def test_delay0_bit_exact_host(name, R):
    n, d, gamma = 8, 5, 0.2
    _, grad_fn = _quadratic(n, d)
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    x0 = jnp.asarray(np.random.default_rng(3).normal(size=(n, d)),
                     jnp.float32)
    sync = alg.from_rule(engine.make_rule(name, gamma, R=R))
    zero = alg.from_rule(engine.make_rule(name, gamma, R=R, delay=0))
    wps = sync.weights_per_step
    key = jax.random.key(0)
    sa = sync.warm(sync.init(x0), grad_fn, key)
    sb = zero.warm(zero.init(x0), grad_fn, key)
    for k in range(3):
        Ws = jnp.asarray(sched.stacked(k * wps, max(wps, 1)))
        sa = sync.step(sa, grad_fn, Ws, key)
        sb = zero.step(sb, grad_fn, Ws, key)
    _assert_bit_exact(sa.x, sb.x)


def test_delay0_bit_exact_dist_dense_and_auto():
    model = ToyModel()
    n, gamma, R = 8, 0.1, 2
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    plan = sched.plan()
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    batch = _toy_batch(n, R, 3, model.d, seed=0)
    wps = engine.make_rule("mc_dsgt", gamma=gamma, R=R).weights_per_step

    states = {}
    for tag, kw in [("sync", {}), ("d0", {"delay": 0})]:
        init_s, warm, step = dsteps.make_train_step(
            model, None, algo="mc_dsgt", gamma=gamma, R=R, **kw)
        s = warm(init_s(jax.random.key(0), n, jnp.float32), batch)
        for k in range(3):
            Ws = jnp.asarray(sched.stacked(k * wps, wps))
            s, _ = jax.jit(step)(s, batch, Ws)
        states[tag] = s
    _assert_bit_exact(states["sync"].x, states["d0"].x)

    init_a, warm_a, step_a = dsteps.make_train_step(
        model, None, algo="mc_dsgt", gamma=gamma, R=R, gossip_impl="auto",
        plan=plan, delay=0)
    sa = warm_a(init_a(jax.random.key(0), n, jnp.float32), batch)
    for k in range(3):
        sa, _ = step_a(sa, batch, tensors, (k * wps) % plan.period)
    assert _tree_err(states["sync"].x, sa.x) < 1e-6


# ---------------------------------------------------------------------------
# 2. delay=d semantics: the hand-rolled recursion is the oracle
# ---------------------------------------------------------------------------

def test_delay1_dsgd_matches_manual_recursion():
    """Stale-window DSGD, delay=1:  z_t = x_t - γ g_t;
    x_{t+1} = z_t + (W_t q_0 - q_0);  queue <- [z_t]  (q seeded with x_0).
    """
    n, d, gamma, steps = 6, 4, 0.3, 5
    centers, grad_fn = _quadratic(n, d, seed=1)
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    x0 = jnp.asarray(np.random.default_rng(7).normal(size=(n, d)),
                     jnp.float32)

    algo = alg.from_rule(engine.make_rule("dsgd", gamma, delay=1))
    s = algo.init(x0)
    key = jax.random.key(0)

    x, q = x0, x0  # queue of length 1, seeded with x0
    for k in range(steps):
        W = jnp.asarray(sched.stacked(k, 1))[0]
        s = algo.step(s, grad_fn, W[None], key)
        z = x - gamma * grad_fn(x, None)
        x = z + (W @ q - q)
        q = z
    assert _tree_err(s.x, x) < 1e-5


def test_delay_dense_equals_auto_dist():
    model = ToyModel()
    n, gamma, R, delay = 8, 0.1, 2, 2
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    plan = sched.plan()
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    batch = _toy_batch(n, R, 3, model.d, seed=0)
    wps = engine.make_rule("mc_dsgt", gamma=gamma, R=R).weights_per_step

    init_d, warm_d, step_d = dsteps.make_train_step(
        model, None, algo="mc_dsgt", gamma=gamma, R=R, delay=delay)
    init_a, warm_a, step_a = dsteps.make_train_step(
        model, None, algo="mc_dsgt", gamma=gamma, R=R, gossip_impl="auto",
        plan=plan, delay=delay)
    sd = warm_d(init_d(jax.random.key(0), n, jnp.float32), batch)
    sa = warm_a(init_a(jax.random.key(0), n, jnp.float32), batch)
    for k in range(4):
        Ws = jnp.asarray(sched.stacked(k * wps, wps))
        sd, _ = jax.jit(step_d)(sd, batch, Ws)
        sa, _ = step_a(sa, batch, tensors, (k * wps) % plan.period)
    assert _tree_err(sd.x, sa.x) < 1e-5
    # buffers advanced: queue depth == delay, oldest-first
    assert len(sd.buf[0]) == delay and len(sd.buf[1]) == delay


# ---------------------------------------------------------------------------
# 3. The overlap proof
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delay,expect", [(0, False), (1, True)])
def test_overlap_report_proves_mix_grad_independence(delay, expect):
    n, d, gamma = 6, 4, 0.2
    _, grad_fn = _quadratic(n, d)
    algo = alg.from_rule(engine.make_rule("mc_dsgt", gamma, R=2,
                                          delay=delay))
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    wps = algo.weights_per_step
    x0 = jnp.zeros((n, d))
    key = jax.random.key(0)
    state = algo.warm(algo.init(x0), grad_fn, key)
    Ws = jnp.asarray(sched.stacked(0, wps))
    rep = overlap_report(lambda s: algo.step(s, grad_fn, Ws, key), state)
    assert rep["mix_eqns"] > 0 and rep["grad_eqns"] > 0
    assert rep["overlapped"] is expect


# ---------------------------------------------------------------------------
# 4. Tracker mean invariance survives the stale window
# ---------------------------------------------------------------------------

def test_tracker_mean_invariant_under_delay():
    n, d, gamma = 8, 5, 0.15
    _, grad_fn = _quadratic(n, d, hetero=3.0)
    algo = alg.from_rule(engine.make_rule("mc_dsgt", gamma, R=2, delay=1))
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    wps = algo.weights_per_step
    x0 = jnp.zeros((n, d))
    key = jax.random.key(0)
    s = algo.warm(algo.init(x0), grad_fn, key)
    for k in range(4):
        Ws = jnp.asarray(sched.stacked(k * wps, wps))
        s = algo.step(s, grad_fn, Ws, key)
    # h-bar == g-bar: each doubly-stochastic stale correction is mean-free
    assert _tree_err(jnp.mean(s.h, 0), jnp.mean(s.g_prev, 0)) < 1e-5


# ---------------------------------------------------------------------------
# 5. comm_interval gating
# ---------------------------------------------------------------------------

def test_comm_interval_skips_mix_on_off_steps():
    n, d, gamma = 6, 4, 0.25
    centers, grad_fn = _quadratic(n, d, seed=2)
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    x0 = jnp.asarray(np.random.default_rng(5).normal(size=(n, d)),
                     jnp.float32)
    algo = alg.from_rule(engine.make_rule("dsgd", gamma, comm_interval=2))
    s = algo.init(x0)
    key = jax.random.key(0)
    x = x0
    for k in range(4):
        W = jnp.asarray(sched.stacked(k, 1))
        s = algo.step(s, grad_fn, W, key)
        z = x - gamma * grad_fn(x, None)
        x = (W[0] @ z) if k % 2 == 0 else z  # odd steps: pure local update
    assert _tree_err(s.x, x) < 1e-5


def test_comm_interval_rejects_compression():
    from repro.core import compress
    with pytest.raises(ValueError, match="comm_interval"):
        engine.make_rule("dsgd", 0.1, comm_interval=2,
                         compression=compress.CompressionConfig(
                             scheme="sign", group=4))


# ---------------------------------------------------------------------------
# 6. Two-level hierarchical lowering
# ---------------------------------------------------------------------------

def _pod_matrix(m, p, seed=0):
    """W = B ⊗ J_p with B a random symmetric doubly-stochastic pod mixer."""
    rng = np.random.default_rng(seed)
    B = np.eye(m)
    for _ in range(3):  # a few symmetric pairwise averagings keep B ds
        i, j = rng.choice(m, 2, replace=False)
        P = np.eye(m)
        P[i, i] = P[j, j] = 0.5
        P[i, j] = P[j, i] = 0.5
        B = P @ B @ P
    assert not np.allclose(B, np.ones((m, m)) / m)  # stays non-complete
    return np.kron(B, np.ones((p, p)) / p), B


def test_planner_detects_two_level_factorization():
    m, p = 4, 4
    W, B = _pod_matrix(m, p)
    rd = gossip.plan_round(W, pods=p)
    assert rd.kind == "two_level" and rd.pods == p
    np.testing.assert_allclose(rd.pod_B, B, atol=1e-12)
    np.testing.assert_allclose(rd.as_dense(), W, atol=1e-12)
    # without the pods hint the same matrix stays dense
    assert gossip.plan_round(W).kind == "dense"
    # structured kinds keep priority: the complete graph is NOT two_level
    J = np.ones((m * p, m * p)) / (m * p)
    assert gossip.plan_round(J, pods=p).kind == "complete"


def test_two_level_mix_matches_dense():
    m, p = 4, 4
    W, B = _pod_matrix(m, p, seed=3)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m * p, 7)),
                    jnp.float32)
    out = alg.two_level_mix(jnp.asarray(B, jnp.float32), p, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(W, np.float32) @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)


def test_hierarchical_topology_dense_equals_auto():
    base = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=8, m=32),
        data=exp.DataSpec(batch=4),
        algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=0.2, R=2),
        topology=exp.TopologySpec(kind="hierarchical", pods=3,
                                  local_steps=2),
        run=exp.RunSpec(steps=4, nodes=12))
    dense = exp.run(base, quiet=True).history
    auto = exp.run(dataclasses.replace(
        base, run=dataclasses.replace(base.run, gossip_impl="auto")),
        quiet=True).history
    assert dense and [t for t, _ in dense] == [t for t, _ in auto]
    for (_, ld), (_, la) in zip(dense, auto):
        np.testing.assert_allclose(ld, la, rtol=1e-5)


def test_plan_pods_property_and_tensors():
    m, p = 4, 2
    W, B = _pod_matrix(m, p, seed=1)
    sched = gossip.WeightSchedule(matrices=(W,))
    plan = sched.plan(0, 3, pods=p)
    assert all(r.kind == "two_level" for r in plan.rounds)
    assert plan.pods == p
    t = plan.tensors()
    assert t["pod_B"].shape == (3, m, m)
    np.testing.assert_allclose(t["pod_B"][0], B.astype(np.float32),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# 7. Spec surface: new fields round-trip and validate
# ---------------------------------------------------------------------------

def test_spec_roundtrip_new_fields():
    s = exp.ExperimentSpec(
        algorithm=exp.AlgorithmSpec(name="dsgd", delay=2, comm_interval=3),
        topology=exp.TopologySpec(kind="hierarchical", pods=4))
    assert exp.from_json(exp.to_json(s)) == s
    d = exp.to_dict(s)
    assert d["algorithm"]["delay"] == 2
    assert d["topology"]["pods"] == 4


@pytest.mark.parametrize("field,value,match", [
    ("algorithm.delay", -1, "delay"),
    ("algorithm.comm_interval", 0, "comm_interval"),
    ("topology.pods", 0, "pods"),
    ("topology.pods", 5, "pods"),  # 5 does not divide nodes=8
])
def test_build_validates_new_fields(field, value, match):
    spec = exp.with_field(exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=4, m=8),
        run=exp.RunSpec(steps=1, nodes=8)), field, value)
    with pytest.raises(ValueError, match=match):
        exp.run(spec, quiet=True)


def test_delay_convergence_within_tolerance_of_sync():
    """Figure-2-style sanity at test scale: a short random-sun logreg run
    under delay 1/2 lands within a few percent of the synchronous final
    loss (the bench asserts 2% at full length)."""
    def run(delay):
        spec = exp.ExperimentSpec(
            model=exp.ModelRef(kind="logreg", d=8, m=64),
            data=exp.DataSpec(batch=8),
            algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=0.25, R=2,
                                        delay=delay),
            topology=exp.TopologySpec(kind="random-sun"),
            run=exp.RunSpec(steps=30, nodes=8))
        hist = exp.run(spec, quiet=True).history
        return hist[0][1], hist[-1][1]

    init, base = run(0)
    assert base < 0.1 * init  # the sync run converges at this scale
    for d in (1, 2):
        _, final = run(d)
        # staleness shifts the trajectory by < 1% of the initial loss
        assert final < 0.1 * init
        assert abs(final - base) < 0.01 * init


# ---------------------------------------------------------------------------
# 8. Staleness telemetry
# ---------------------------------------------------------------------------

def test_stale_gap_reported_when_delayed():
    spec = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=8, m=16),
        data=exp.DataSpec(batch=4),
        algorithm=exp.AlgorithmSpec(name="dsgd", gamma=0.3, delay=1),
        run=exp.RunSpec(steps=8, nodes=4))
    res = exp.run(spec, quiet=True)
    # delay alone warrants the recorder (no faults/mobility/telemetry path)
    assert res.telemetry is not None
    rows = [h for h in res.telemetry.history if "stale_gap" in h]
    assert rows, "delay>0 runs must report the stale-window gap"
    landed = [h["stale_gap"] for h in rows if h["stale_gap"] is not None]
    assert landed and all(0.0 <= g <= 1.0 for g in landed)
