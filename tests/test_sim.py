"""repro.sim tests: mobility schedules, channel faults, weight repair,
realized-plan lowering, and mixing telemetry.

Covers the ISSUE acceptance path end to end: seed-stream determinism under
out-of-order queries, Assumption 3 on repaired matrices for every channel
model (plus the documented row-stochastic fallback for directed masks),
degraded-plan mixing exact against the reconstructed dense matrices on
both runtimes, and the 16-node geometric-mobility resilience demo under
20% iid link drop."""

import json
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg, driver, gossip, topology as topo
from repro.sim import (BernoulliDropChannel, GilbertElliottChannel,
                       LinkLatencyModel, NodeChurn, StragglerInjection,
                       TelemetryRecorder, combined_mask,
                       consensus_distance, empirical_effective_diameter,
                       random_geometric_schedule, random_waypoint_schedule,
                       realize_weight_schedule, repair_weights,
                       unit_disk_adjacency, windowed_spectral_gap)

N = 12

CHANNEL_MODELS = {
    "bernoulli": BernoulliDropChannel(0.3, seed=3),
    "gilbert_elliott": GilbertElliottChannel(0.2, p_good=0.3, seed=4),
    "churn": NodeChurn(0.2, seed=5),
    "straggler": StragglerInjection(0.3, seed=6),
}


def _matching_ws(n=N, horizon=16, seed=0):
    return gossip.schedule_from_topology(
        topo.resampled_matching_schedule(n, seed=seed), horizon=horizon)


# ---------------------------------------------------------------------------
# Mobility schedules
# ---------------------------------------------------------------------------

def test_unit_disk_adjacency_matches_pairwise_distance():
    rng = np.random.default_rng(0)
    pos = rng.random((N, 2))
    adj = unit_disk_adjacency(pos, 0.4)
    assert np.array_equal(adj, adj.T) and adj.diagonal().all()
    for i in range(N):
        for j in range(N):
            if i != j:
                d = np.linalg.norm(pos[i] - pos[j])
                assert adj[i, j] == (d <= 0.4)


def test_waypoint_mobility_is_temporally_correlated():
    """Positions move continuously: per-round displacement is bounded by
    the leg length / leg_rounds, unlike the iid geometric draw."""
    sched = random_waypoint_schedule(N, leg_rounds=8, seed=1)
    for t in range(20):
        step = np.abs(sched.positions(t + 1) - sched.positions(t)).max()
        assert step <= np.sqrt(2) / 8 + 1e-12
    # geometric teleports: same bound would a.s. fail somewhere
    geo = random_geometric_schedule(N, seed=1)
    steps = [np.abs(geo.positions(t + 1) - geo.positions(t)).max()
             for t in range(20)]
    assert max(steps) > np.sqrt(2) / 8


def test_mobility_feeds_weight_schedule_and_planner():
    for sched in (random_geometric_schedule(N, 0.45, seed=0),
                  random_waypoint_schedule(N, 0.45, seed=0)):
        assert sched.period is None
        ws = gossip.schedule_from_topology(sched, horizon=6)
        plan = ws.plan(0, 6)  # validates vs dense + Assumption 3
        assert plan.period == 6


# ---------------------------------------------------------------------------
# Satellite: seed-stream determinism under out-of-order / repeated queries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,stream", [
    ("resampled-matching", topo.resampled_matching_schedule(N, seed=9)),
    ("geometric", random_geometric_schedule(N, seed=9)),
    ("waypoint", random_waypoint_schedule(N, seed=9)),
])
def test_schedule_determinism_out_of_order(name, stream):
    ts = list(range(24))
    in_order = {t: np.array(stream(t)) for t in ts}
    kinds = {t: stream.structure(t).kind for t in ts}
    shuffled = ts[:]
    random.Random(7).shuffle(shuffled)
    for t in shuffled + shuffled:  # out-of-order AND repeated
        assert np.array_equal(stream(t), in_order[t]), (name, t)
        assert stream.structure(t).kind == kinds[t], (name, t)


@pytest.mark.parametrize("name", sorted(CHANNEL_MODELS))
def test_channel_mask_determinism_out_of_order(name):
    model = CHANNEL_MODELS[name]
    ts = list(range(24))
    in_order = {t: model.mask(t, N) for t in ts}
    shuffled = ts[:]
    random.Random(3).shuffle(shuffled)
    for t in shuffled + shuffled:
        assert np.array_equal(model.mask(t, N), in_order[t]), (name, t)


def test_gilbert_elliott_is_bursty():
    """Bad states persist: consecutive-round state agreement beats the iid
    rate, and the chain still visits both states."""
    ge = GilbertElliottChannel(0.15, p_good=0.2, seed=11, block=64)
    states = np.stack([ge.bad_state(t, N) for t in range(60)])
    frac_bad = states.mean()
    assert 0.05 < frac_bad < 0.9
    same = (states[1:] == states[:-1]).mean()
    iid_same = frac_bad ** 2 + (1 - frac_bad) ** 2
    assert same > iid_same + 0.05


# ---------------------------------------------------------------------------
# Satellite: fault repair validity (Assumption 3 / row-stochastic fallback)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CHANNEL_MODELS))
@pytest.mark.parametrize("base", ["matching", "mobility", "sun"])
def test_repaired_matrices_satisfy_assumption3(name, base):
    """For every channel model x base topology, each realized round passes
    check_assumption3 on its realized sparsity pattern."""
    if base == "matching":
        ideal = _matching_ws()
    elif base == "mobility":
        ideal = gossip.schedule_from_topology(
            random_geometric_schedule(N, 0.5, seed=2), horizon=16)
    else:
        ideal = gossip.theorem3_weight_schedule(N, 0.75)
    realized = realize_weight_schedule(ideal, [CHANNEL_MODELS[name]],
                                       rounds=16)
    for t in range(16):
        W = realized(t)
        adj = np.abs(W) > 1e-12
        np.fill_diagonal(adj, True)
        assert np.array_equal(W, W.T), "repair must stay symmetric"
        gossip.check_assumption3(W, adj)


def test_repair_directed_mask_is_row_stochastic_fallback():
    """A directed (asymmetric) drop breaks double stochasticity: rows still
    sum to 1 (each node still takes a convex combination of what it
    received) but columns need not — the documented fallback, and why
    realize_weight_schedule symmetrizes every mask."""
    W = gossip.metropolis_weights(topo.ring_graph(6))
    mask = np.ones((6, 6), dtype=bool)
    mask[0, 1] = False  # 1 -> 0 lost, 0 -> 1 survives
    repaired = repair_weights(W, mask)
    ones = np.ones(6)
    np.testing.assert_allclose(repaired @ ones, ones, atol=1e-12)
    assert abs((ones @ repaired)[1] - 1.0) > 1e-3  # column sums broken
    with pytest.raises(AssertionError):
        gossip.check_assumption3(repaired)
    # the symmetrized mask restores Assumption 3
    sym = repair_weights(W, mask & mask.T)
    gossip.check_assumption3(sym)


def test_repair_identities():
    W = gossip.metropolis_weights(topo.sun_shaped_graph(8, [0, 1]))
    full = np.ones((8, 8), dtype=bool)
    np.testing.assert_array_equal(repair_weights(W, full), W)
    none = np.zeros((8, 8), dtype=bool)
    np.testing.assert_array_equal(repair_weights(W, none), np.eye(8))


def test_combined_mask_symmetrizes_and_keeps_diagonal():
    m = combined_mask([CHANNEL_MODELS["bernoulli"],
                       CHANNEL_MODELS["churn"]], 3, N)
    assert np.array_equal(m, m.T) and m.diagonal().all()


# ---------------------------------------------------------------------------
# Realized plans: lowering selection + exactness
# ---------------------------------------------------------------------------

def test_degraded_matching_lowers_to_matching_and_empty():
    """Partially dropped matchings keep the one-peer lowering (perm fixes
    the unmatched nodes); fully dropped rounds lower to free empty
    rounds."""
    ideal = _matching_ws(horizon=12)
    realized = realize_weight_schedule(
        ideal, [BernoulliDropChannel(0.5, seed=1)], rounds=12)
    plan = realized.plan(0, 12)
    assert set(plan.kinds) <= {"matching", "empty"}
    assert "matching" in plan.kinds
    partial = [rd for rd in plan.rounds if rd.kind == "matching"
               and (rd.perm == np.arange(N)).any()
               and (rd.perm != np.arange(N)).any()]
    assert partial, "50% drop should leave some partial matchings"
    for rd in partial:
        fixed = rd.perm == np.arange(N)
        assert np.all(rd.w_peer[fixed] == 0.0)
    # total loss => identity round => empty
    dead = realize_weight_schedule(
        ideal, [BernoulliDropChannel(1.0, seed=1)], rounds=4)
    assert set(dead.plan(0, 4).kinds) == {"empty"}


@pytest.mark.parametrize("name", sorted(CHANNEL_MODELS))
def test_degraded_plan_mixing_bitexact_vs_reconstructed_dense(name):
    """Per round: mixing through the structured lowering == mixing with the
    round's reconstructed dense matrix, bit for bit (matching base, so the
    lowerings exercised are matching/empty)."""
    ideal = _matching_ws()
    realized = realize_weight_schedule(ideal, [CHANNEL_MODELS[name]],
                                       rounds=16)
    plan = realized.plan(0, 16)
    assert set(plan.kinds) <= {"matching", "empty"}
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    mixer = alg.make_plan_mixer(plan, mode="static")
    x = jax.random.normal(jax.random.key(0), (N, 7))
    for t, rd in enumerate(plan.rounds):
        got = np.asarray(mixer(tensors, t, 1, x))
        want = np.asarray(alg.mix(jnp.asarray(rd.as_dense(), jnp.float32), x))
        np.testing.assert_array_equal(got, want, err_msg=f"round {t}")


def test_realized_window_planned_equals_dense_multi_consensus():
    """Whole realized window through the plan dispatcher == the dense
    matrix-product reference (the lowering-correctness acceptance check on
    the host runtime)."""
    ideal = gossip.schedule_from_topology(
        random_geometric_schedule(16, 0.45, seed=0), horizon=12)
    realized = realize_weight_schedule(
        ideal, [BernoulliDropChannel(0.2, seed=1),
                GilbertElliottChannel(0.1, seed=2)], rounds=12)
    plan = realized.plan(0, 12)
    tree = {"a": jax.random.normal(jax.random.key(1), (16, 5)),
            "b": jax.random.normal(jax.random.key(2), (16, 3, 2))}
    want = alg.multi_consensus(jnp.asarray(realized.stacked(0, 12)), tree)
    mixer = alg.make_plan_mixer(plan, mode="static")
    got = mixer(jax.tree.map(jnp.asarray, plan.tensors()), 0, 12, tree)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert float(jnp.abs(w - g).max()) < 1e-5


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

def test_consensus_distance_zero_iff_consensus():
    x = jnp.ones((4, 3))
    assert consensus_distance({"w": x}) == 0.0
    x2 = x.at[0].set(2.0)
    assert consensus_distance({"w": x2}) > 0.5


def test_windowed_spectral_gap_and_diameter():
    n = 8
    J = np.ones((n, n)) / n
    assert abs(windowed_spectral_gap(np.stack([J])) - 1.0) < 1e-9
    eye = np.stack([np.eye(n)])
    assert abs(windowed_spectral_gap(eye) - 0.0) < 1e-9
    comp = np.ones((1, n, n), dtype=bool)
    assert empirical_effective_diameter(comp) == 1
    assert empirical_effective_diameter(np.eye(n, dtype=bool)[None]) is None


def test_telemetry_recorder_and_json_dump(tmp_path):
    ideal = _matching_ws(n=8, horizon=24, seed=1)
    realized = realize_weight_schedule(
        ideal, [BernoulliDropChannel(0.2, seed=2)], rounds=24)
    rec = TelemetryRecorder(realized, wps=2, window=8)

    class S:
        x = jnp.ones((8, 3)).at[0].set(0.0)

    entry = rec.record(3, 12, S(), {"loss": jnp.float32(1.5)}, 0.01)
    assert entry["loss"] == 1.5 and entry["window"] == [4, 12]
    assert entry["consensus"] > 0 and 0.0 <= entry["spectral_gap"] <= 1.0
    assert sum(entry["kinds"].values()) == 8
    path = str(tmp_path / "telem.json")
    rec.dump(path)
    blob = json.load(open(path))
    assert set(blob) == {"fields", "history"}
    assert blob["history"][0]["step"] == 3
    assert "eff_diameter" in blob["fields"]


# ---------------------------------------------------------------------------
# End-to-end: resilience demo + both runtimes
# ---------------------------------------------------------------------------

def test_e2e_mobility_linkdrop_resilience_host():
    """Acceptance: 16-node geometric mobility under 20% iid link drop —
    mc_dsgt and gt_local still decrease the loss, and the telemetry
    history reports realized effective diameter and consensus distance."""
    n, d = 16, 32
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 2.0)

    def grad_fn(xs, key):
        return xs - centers + 0.3 * jax.random.normal(key, xs.shape)

    def eval_fn(xb):
        return jnp.sum((xb - centers.mean(0)) ** 2)

    ideal = gossip.schedule_from_topology(
        random_geometric_schedule(n, 0.45, seed=0), horizon=200)
    realized = realize_weight_schedule(
        ideal, [BernoulliDropChannel(0.2, seed=1)], rounds=200)
    for name, algo in [("mc_dsgt", alg.mc_dsgt(0.2, R=2)),
                       ("gt_local", alg.gt_local(0.2))]:
        steps = 160 // algo.weights_per_step
        telem = TelemetryRecorder(realized, wps=algo.weights_per_step)
        _, hist = alg.run(algo, jnp.zeros((n, d)), grad_fn, realized, steps,
                          jax.random.key(0), eval_fn=eval_fn,
                          eval_every=max(1, steps - 1), telemetry=telem)
        first, last = float(hist[0][1]), float(hist[-1][1])
        assert last < first, (name, first, last)
        diams = [e["eff_diameter"] for e in telem.history
                 if e["eff_diameter"] is not None]
        assert diams, "telemetry must report realized effective diameters"
        assert all(e["consensus"] >= 0 for e in telem.history)


def test_train_cli_mobility_linkdrop_auto_matches_dense(tmp_path):
    """Dist runtime: --gossip-impl auto == dense, step for step, on the
    realized (mobility + 20% drop) schedule; the telemetry JSON lands on
    disk with the realized-window fields."""
    from repro.launch.train import main as train_main
    telem_path = str(tmp_path / "telem.json")
    base = ["--arch", "qwen1.5-0.5b", "--preset", "reduced", "--steps", "2",
            "--nodes", "4", "--batch", "1", "--seq", "16",
            "--topology", "geometric-mobility", "--link-drop", "0.2"]
    dense = train_main(base + ["--gossip-impl", "dense",
                               "--telemetry", telem_path])
    auto = train_main(base + ["--gossip-impl", "auto"])
    assert len(dense) == len(auto) == 2
    for hd, ha in zip(dense, auto):
        np.testing.assert_allclose(hd["loss"], ha["loss"], rtol=2e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(hd["consensus"], ha["consensus"],
                                   atol=1e-3)
    blob = json.load(open(telem_path))
    for e in blob["history"]:
        assert {"consensus", "spectral_gap", "eff_diameter",
                "kinds"} <= set(e)


def test_train_cli_churn_straggler_burst_smoke():
    """The full degradation stack (bursty loss + churn + stragglers) runs
    end to end through the CLI and keeps the loss finite."""
    from repro.launch.train import main as train_main
    hist = train_main(["--arch", "qwen1.5-0.5b", "--preset", "reduced",
                       "--steps", "2", "--nodes", "4", "--batch", "1",
                       "--seq", "16", "--topology", "waypoint-mobility",
                       "--burst-loss", "0.1", "--churn", "0.1",
                       "--straggler", "0.2", "--gossip-impl", "auto"])
    assert len(hist) == 2
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_run_algorithm_auto_equals_dense_on_ideal_schedules():
    """The new host plan path (driver.run_algorithm gossip_impl='auto')
    reproduces the dense path on the structured paper schedules too."""
    n, d = 8, 8
    centers = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)))

    def grad_fn(xs, key):
        return xs - centers + 0.1 * jax.random.normal(key, xs.shape)

    def eval_fn(xb):
        return jnp.sum((xb - centers.mean(0)) ** 2)

    from repro import optim
    sched = gossip.theorem3_weight_schedule(n, 0.75)
    for algo in (alg.dsgd(0.2), alg.mc_dsgt(0.2, R=2),
                 # regression: the plan path must honor the local-optimizer
                 # hook, not silently fall back to the raw update
                 alg.dsgd(0.2, local_opt=optim.adam()),
                 alg.local_sgd(0.2, local_opt=optim.momentum())):
        _, hd = driver.run_algorithm(algo, jnp.zeros((n, d)), grad_fn, sched,
                                     6, jax.random.key(0), eval_fn=eval_fn)
        _, ha = driver.run_algorithm(algo, jnp.zeros((n, d)), grad_fn, sched,
                                     6, jax.random.key(0), eval_fn=eval_fn,
                                     gossip_impl="auto")
        for (t1, e1), (t2, e2) in zip(hd, ha):
            assert t1 == t2
            np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4,
                                       atol=1e-6)
