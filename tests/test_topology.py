"""Unit tests for time-varying topologies and effective distance (paper §3)."""

import math

import numpy as np
import pytest

from repro.core import topology as topo


def test_sun_shaped_star_and_complete():
    # |C| = 1 -> star; |C| = n (or n-1) -> complete (paper Def. 1 remark)
    star = topo.sun_shaped_graph(8, [0])
    assert np.array_equal(star, topo.star_graph(8, 0))
    comp = topo.sun_shaped_graph(8, list(range(8)))
    assert np.array_equal(comp, topo.complete_graph(8))
    comp2 = topo.sun_shaped_graph(8, list(range(7)))
    # |C| = n-1: node 7 connects to all of C and C is complete -> complete graph
    assert np.array_equal(comp2, topo.complete_graph(8))


def test_sun_shaped_structure():
    adj = topo.sun_shaped_graph(8, [2, 3])
    # rim-rim links absent
    assert not adj[0, 1] and not adj[5, 7]
    # center-anything present, symmetric
    assert adj[2, 6] and adj[6, 2] and adj[2, 3]
    assert np.array_equal(adj, adj.T)


def test_static_distance_reduces_to_graph_distance():
    # Definition 2 remark: static schedule -> canonical graph distance
    ring = topo.StaticSchedule(topo.ring_graph(8))
    assert topo.effective_distance(ring, [0], [4]) == 4
    assert topo.effective_distance(ring, [0], [1]) == 1
    assert topo.effective_diameter(ring) == 4
    star = topo.StaticSchedule(topo.star_graph(6, 0))
    assert topo.effective_diameter(star) == 2


@pytest.mark.parametrize("n,beta", [(8, 0.5), (16, 0.75), (16, 1 - 1 / 16),
                                    (32, 0.9), (12, 0.0), (9, 0.5)])
def test_theorem3_distance_matches_formula(n, beta):
    """Effective distance of the constructed schedule == eq. (5)."""
    size = max(1, math.ceil(n / 4))
    I1 = tuple(range(size))
    I2 = tuple(range(n - size, n))
    sched = topo.sun_shaped_schedule(n, beta, avoid=I1 + I2)
    got = topo.effective_distance(sched, I1, I2, period=sched.period)
    want = topo.theorem3_distance_formula(n, beta, size, size)
    assert got == want, (got, want)


def test_theorem3_distance_theta_bound():
    """dist = Theta(1/(1-beta)) when the far sets have Omega(n) mass."""
    n = 32
    for beta in [0.5, 0.75, 0.9, 1 - 1 / n]:
        size = math.ceil(n / 4)
        d = topo.theorem3_distance_formula(n, beta, size, size)
        lo = (1 - size * 2 / n) / (1 - beta) / 2
        hi = (1 - size * 2 / n) / (1 - beta) + 1
        assert lo <= d <= hi + 1, (beta, d, lo, hi)


def test_one_peer_exponential_every_node_one_peer():
    sched = topo.one_peer_exponential_schedule(16)
    for t in range(sched.period):
        adj = sched(t)
        offdiag = adj & ~np.eye(16, dtype=bool)
        # every node has exactly one peer at each round
        assert (offdiag.sum(axis=1) == 1).all()
    # full mixing within log2(n) rounds: diameter == log2 n hops... effective
    # diameter over the periodic schedule is at most period
    assert topo.effective_diameter(sched) <= sched.period + 1


def test_federated_schedule():
    sched = topo.federated_schedule(8, local_steps=3)
    assert sched.period == 4
    assert np.array_equal(sched(3), topo.complete_graph(8))
    # the three local rounds are identity graphs
    for t in range(3):
        assert np.array_equal(sched(t), np.eye(8, dtype=bool))
    # effective distance: any two nodes meet at the global-averaging round
    assert topo.effective_diameter(sched) <= 4


@pytest.mark.parametrize("n,local_steps", [(8, 3), (8, 5), (16, 4)])
def test_federated_effective_diameter_regression(n, local_steps):
    """Regression: the federated schedule's effective diameter is exactly 1.

    Definition 2 takes the MIN over start rounds, and starting at the
    global-averaging round connects every pair in one round — so despite
    ``local_steps`` silent rounds per period, the effective diameter (and
    hence the Theorem 2 graph term) is that of the complete graph."""
    sched = topo.federated_schedule(n, local_steps)
    assert topo.effective_diameter(sched, period=sched.period) == 1


def test_effective_distance_min_over_start_round():
    """Definition 2 takes the min over start rounds: starting right before
    the averaging round of a federated schedule gives distance 1."""
    sched = topo.federated_schedule(8, local_steps=5)
    assert topo.effective_distance(sched, [0], [5], period=sched.period) == 1


def test_classify_adjacency_round_structures():
    """structure(t) descriptors: each graph family maps to its tag."""
    assert topo.classify_adjacency(topo.complete_graph(8)).kind == "complete"
    assert topo.classify_adjacency(np.eye(8, dtype=bool)).kind == "empty"
    star = topo.classify_adjacency(topo.star_graph(8, 2))
    assert star.kind == "sun" and star.center == (2,)
    sun = topo.classify_adjacency(topo.sun_shaped_graph(9, [1, 4]))
    assert sun.kind == "sun" and sun.center == (1, 4)
    m = topo.classify_adjacency(topo.one_peer_exponential_schedule(8)(0))
    assert m.kind == "matching" and m.perm == (1, 0, 3, 2, 5, 4, 7, 6)
    assert topo.classify_adjacency(topo.ring_graph(8)).kind == "dense"
    # schedules expose the per-round descriptor directly
    fed = topo.federated_schedule(8, 2)
    assert [fed.structure(t).kind for t in range(3)] == \
        ["empty", "empty", "complete"]


@pytest.mark.parametrize("n,beta", [(8, 0.5), (16, 0.75), (16, 1 - 1 / 16),
                                    (12, 0.0)])
def test_effective_diameter_vectorized_equals_pairwise(n, beta):
    """The all-pairs frontier propagation must equal the O(n^2) pairwise
    reference scan it replaced, pinned on the Theorem 3 schedules (and a
    couple of structurally different ones below)."""
    sched = topo.sun_shaped_schedule(n, beta)
    assert topo.effective_diameter(sched, period=sched.period) == \
        topo._effective_diameter_pairwise(sched, period=sched.period)


def test_effective_diameter_vectorized_equals_pairwise_other_families():
    for sched in (topo.StaticSchedule(topo.ring_graph(9)),
                  topo.one_peer_exponential_schedule(8),
                  topo.federated_schedule(8, 3),
                  topo.erdos_renyi_schedule(10, 0.2, period=4, seed=3)):
        assert topo.effective_diameter(sched) == \
            topo._effective_diameter_pairwise(sched)


def test_classify_partial_matching():
    """Degraded (partial) matchings classify as matching with fixed points
    — the lowering channel faults rely on (repro.sim)."""
    adj = np.eye(8, dtype=bool)
    adj[0, 1] = adj[1, 0] = True
    adj[4, 6] = adj[6, 4] = True
    s = topo.classify_adjacency(adj)
    assert s.kind == "matching"
    assert s.perm == (1, 0, 2, 3, 6, 5, 4, 7)


def test_random_matching_schedule():
    sched = topo.random_matching_schedule(12, period=8, seed=1)
    for t in range(sched.period):
        adj = sched(t)
        off = adj & ~np.eye(12, dtype=bool)
        assert (off.sum(axis=1) == 1).all(), "not a perfect matching"
        assert np.array_equal(adj, adj.T)
    # per-round matrices are doubly stochastic on the right sparsity pattern;
    # a single matching has beta = 1 (no per-round contraction — same as
    # one-peer exponential), connectivity comes from the product over the
    # period, which must mix:
    from repro.core import gossip
    ws = gossip.schedule_from_topology(sched)
    for t in range(ws.period):
        gossip.check_assumption3(ws(t), sched(t), beta=1.0)
    assert gossip.consensus_contraction(ws, ws.period) < 0.5
