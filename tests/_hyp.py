"""Optional-hypothesis shim for the property tests.

When ``hypothesis`` is installed (see requirements-dev.txt — CI always
installs it) this re-exports the real ``given`` / ``settings`` /
``strategies``.  When it is absent, the stand-ins mark each property test as
skipped at collection time so the rest of the module still runs.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Any strategy constructor resolves to a stub returning None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()  # mirrors `hypothesis.strategies as st`
