"""Tests for the zero-chain hard instances (paper Appendix B, Lemmas 7-8)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lower_bound as lb


def test_psi_phi_basic():
    assert float(lb.psi(0.4)) == 0.0
    assert float(lb.psi(0.5)) == 0.0
    # psi(1) = exp(1 - 1) = 1
    assert float(lb.psi(1.0)) == pytest.approx(1.0, abs=1e-6)
    # phi(inf) = sqrt(2 pi e); phi(-inf) = 0
    assert float(lb.phi(20.0)) == pytest.approx(math.sqrt(2 * math.pi * math.e), rel=1e-6)
    assert float(lb.phi(-20.0)) == pytest.approx(0.0, abs=1e-6)
    # psi is smooth at the boundary: grad at 0.5 is 0
    g = jax.grad(lambda z: lb.psi(z))(0.5)
    assert float(g) == 0.0


def test_h_split_identity():
    """Lemma 8.1: (h1 + h2) / 2 == h."""
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = jnp.asarray(rng.normal(size=11), jnp.float32)
        lhs = 0.5 * (lb.h1(x) + lb.h2(x))
        np.testing.assert_allclose(float(lhs), float(lb.h(x)), rtol=1e-5, atol=1e-6)


def test_zero_chain_property():
    """prog(grad h(x)) <= prog(x) + 1 — one oracle call advances at most one
    coordinate (Appendix B.1)."""
    d = 12
    rng = np.random.default_rng(1)
    for j in range(0, d, 3):
        x = np.zeros(d, np.float32)
        x[:j] = rng.normal(size=j) + 1.0
        g = jax.grad(lb.h)(jnp.asarray(x))
        assert int(lb.prog(g)) <= j + 1


def test_lemma8_alternating_progress():
    """Lemma 8.2: if prog(x) is odd, grad h1 makes no progress; if even,
    grad h2 makes no progress — nodes must alternate via the network."""
    d = 12
    rng = np.random.default_rng(2)
    for j in range(1, d - 1):
        x = np.zeros(d, np.float32)
        # coordinates past psi's dead zone (|x| > 1/2) so the chain is live
        x[:j] = rng.uniform(1.0, 2.0, size=j)
        assert int(lb.prog(jnp.asarray(x))) == j
        g1 = jax.grad(lb.h1)(jnp.asarray(x))
        g2 = jax.grad(lb.h2)(jnp.asarray(x))
        if j % 2 == 1:
            assert int(lb.prog(g1)) <= j, f"h1 advanced at odd prog {j}"
            assert int(lb.prog(g2)) == j + 1, f"h2 should advance at odd prog {j}"
        else:
            assert int(lb.prog(g2)) <= j, f"h2 advanced at even prog {j}"
            assert int(lb.prog(g1)) == j + 1, f"h1 should advance at even prog {j}"


def test_grad_h_nonzero_before_chain_end():
    """Lemma 7.4: ||grad h||_inf >= 1 whenever x_d = 0."""
    d = 8
    rng = np.random.default_rng(3)
    for j in range(0, d - 1):
        x = np.zeros(d, np.float32)
        x[:j] = rng.normal(size=j)
        g = jax.grad(lb.h)(jnp.asarray(x))
        assert float(jnp.abs(g).max()) >= 1.0 - 1e-5


def test_instance1_oracle_unbiased_and_bounded_variance():
    inst = lb.make_instance1(L=1.0, Delta=1.0, sigma=1.0, n=4, T=64)
    x = jnp.zeros(inst.d, jnp.float32).at[0].set(1.0)
    g = inst.grad_f(x)
    samples = jnp.stack([inst.oracle(x, jax.random.key(s)) for s in range(300)])
    mean = samples.mean(0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g), atol=0.15)
    var = float(jnp.mean(jnp.sum((samples - g[None]) ** 2, axis=-1)))
    assert var <= 1.0 * 1.3  # sigma^2 = 1, allow sampling slack


def test_instance1_oracle_zero_respecting():
    """The oracle can only reveal coordinate prog(x) + 1."""
    inst = lb.make_instance1(L=1.0, Delta=1.0, sigma=1.0, n=4, T=64)
    x = jnp.zeros(inst.d, jnp.float32).at[:3].set(1.0)
    for s in range(10):
        o = inst.oracle(x, jax.random.key(s))
        assert int(lb.prog(o)) <= 4


def test_instance2_smoothness_budget():
    """(14): d * lam^2 <= 2 ell0 Delta / (L delta0)."""
    inst = lb.make_instance2(L=2.0, Delta=1.0, n=16, beta=0.9, T=200)
    assert inst.d * inst.lam ** 2 <= 2 * lb.ELL0 * 1.0 / (2.0 * lb.DELTA0) + 1e-9


def test_instance2_node_assignment():
    inst = lb.make_instance2(L=1.0, Delta=1.0, n=16, beta=0.75, T=100)
    assert inst.set1 == tuple(range(4))
    assert inst.set2 == tuple(range(12, 16))
    x = jnp.ones(inst.d, jnp.float32)
    # middle nodes have zero loss and zero gradient
    assert float(inst.f_i(8, x)) == 0.0
    g = inst.grad_stacked(jnp.broadcast_to(x, (16, inst.d)))
    assert float(jnp.abs(g[8]).max()) == 0.0
    assert float(jnp.abs(g[0]).max()) > 0.0
