"""The §Perf optimization variants must be *exact* (or harmless) rewrites of
the paper-faithful baseline."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import algorithms as alg
from repro.core import gossip, topology as topo
from repro.data import token_stream_for
from repro.dist import steps as dsteps
from repro.models import build, materialize_batch


def _sun_masks(n, beta, rounds):
    graphs = topo.sun_shaped_schedule(n, beta)
    masks = []
    for t in range(rounds):
        adj = graphs(t)
        deg = (adj & ~np.eye(n, dtype=bool)).sum(1)
        masks.append((deg == n - 1).astype(np.float32))
    k = math.ceil(n * (1 - beta))
    delta = n * (1 - beta) / k
    return jnp.asarray(np.stack(masks)), delta


def test_sun_gossip_train_step_matches_dense():
    """gossip_impl='sun' must produce the same trajectory as the dense W."""
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    n, R, beta = 8, 2, 0.75
    stream = token_stream_for(cfg, n, R, 2, 32, seed=0)
    wsched = gossip.theorem3_weight_schedule(n, beta)
    masks, delta = _sun_masks(n, beta, 2 * R)

    init_d, warm_d, step_d = dsteps.make_train_step(model, cfg, gamma=0.05, R=R)
    init_s, warm_s, step_s = dsteps.make_train_step(
        model, cfg, gamma=0.05, R=R, gossip_impl="sun", sun_delta=delta)

    s_d = warm_d(init_d(jax.random.key(0), n, jnp.float32), stream.batch_at(0))
    s_s = warm_s(init_s(jax.random.key(0), n, jnp.float32), stream.batch_at(0))
    W = jnp.asarray(wsched.stacked(0, 2 * R))
    s_d, m_d = jax.jit(step_d)(s_d, stream.batch_at(1), W)
    s_s, m_s = jax.jit(step_s)(s_s, stream.batch_at(1), masks)
    np.testing.assert_allclose(float(m_d["loss"]), float(m_s["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_d.x), jax.tree.leaves(s_s.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)


def test_prefill_last_only_matches_full():
    cfg = configs.get("qwen1.5-0.5b").reduced()
    cfg_opt = dataclasses.replace(cfg, prefill_last_only=True)
    m_base, m_opt = build(cfg), build(cfg_opt)
    params = m_base.init(jax.random.key(0), jnp.float32)
    batch = materialize_batch(cfg, 2, 16, jax.random.key(1), jnp.float32)
    c1 = m_base.init_cache(2, 32, jnp.float32)
    c2 = m_opt.init_cache(2, 32, jnp.float32)
    l1, c1 = m_base.prefill(params, batch, c1)
    l2, c2 = m_opt.prefill(params, batch, c2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_grouped_dispatch_matches_dense_in_training():
    cfg = configs.get("granite-moe-3b-a800m").reduced()
    cfg_opt = dataclasses.replace(cfg, moe_seq_group=32)
    m_base, m_opt = build(cfg), build(cfg_opt)
    params = m_base.init(jax.random.key(0), jnp.float32)
    batch = materialize_batch(cfg, 2, 64, jax.random.key(1), jnp.float32)
    l1 = m_base.train_loss(params, batch)
    l2 = m_opt.train_loss(params, batch)
    # dropless at smoke scale -> identical routing; aux loss averages over
    # groups instead of the full batch, so allow a small difference there
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)


def test_bf16_tracker_state_trains():
    """bf16 h/g_prev must still reduce the loss (H2 validation at smoke
    scale)."""
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    n, R = 4, 2
    stream = token_stream_for(cfg, n, R, 2, 32, seed=0, active_vocab=16)
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    init_s, warm, step = dsteps.make_train_step(
        model, cfg, gamma=0.15, R=R, aux_dtype=jnp.bfloat16)
    state = warm(init_s(jax.random.key(0), n, jnp.float32), stream.batch_at(0))
    step = jax.jit(step)
    losses = []
    t = 0
    for k in range(15):
        W = jnp.asarray(sched.stacked(t, 2 * R))
        state, m = step(state, stream.batch_at(k + 1), W)
        losses.append(float(m["loss"]))
        t += 2 * R
    assert losses[-1] < losses[0] - 0.2, losses
    assert all(np.isfinite(l) for l in losses)


def test_local_momentum_extension_trains():
    """Framework extension: momentum on the gradient tracker (DecentLaM
    flavour) still trains and keeps consensus."""
    from repro.optim import momentum
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    n, R = 4, 2
    stream = token_stream_for(cfg, n, R, 2, 32, seed=0, active_vocab=16)
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    init_s, warm, step = dsteps.make_train_step(
        model, cfg, gamma=0.05, R=R, local_opt=momentum(0.9))
    state = warm(init_s(jax.random.key(0), n, jnp.float32), stream.batch_at(0))
    step = jax.jit(step)
    losses = []
    t = 0
    for k in range(15):
        W = jnp.asarray(sched.stacked(t, 2 * R))
        state, m = step(state, stream.batch_at(k + 1), W)
        losses.append(float(m["loss"]))
        t += 2 * R
    assert losses[-1] < losses[0] - 0.2, losses
