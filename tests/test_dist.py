"""Distributed runtime tests.

jax locks the host device count at first init, so every mesh-dependent test
runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    script = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_unsharded():
    """One MC-DSGT step on a 4x2 mesh must equal the single-device result."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import configs
        from repro.dist import sharding as shd, steps as dsteps
        from repro.models import build
        from repro.data import token_stream_for
        from repro.core import gossip

        cfg = configs.get("qwen1.5-0.5b").reduced()
        model = build(cfg)
        n, R = 4, 2
        sched = gossip.theorem3_weight_schedule(n, 0.5)
        stream = token_stream_for(cfg, n, R, 2, 32, seed=0)
        init_state, warm, step = dsteps.make_train_step(model, cfg,
                                                        gamma=0.05, R=R)
        state0 = init_state(jax.random.key(0), n, jnp.float32)
        state0 = warm(state0, stream.batch_at(0))
        batch = stream.batch_at(1)
        W = jnp.asarray(sched.stacked(0, 2 * R))

        # unsharded reference
        ref_state, ref_m = jax.jit(step)(state0, batch, W)

        # sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            sspec = dsteps.TrainState(
                x=shd.param_specs(state0.x, cfg, mesh, stacked_nodes=True),
                h=shd.param_specs(state0.h, cfg, mesh, stacked_nodes=True),
                g_prev=shd.param_specs(state0.g_prev, cfg, mesh,
                                       stacked_nodes=True),
                step=P())
            bspec = shd.batch_specs(batch, mesh, stacked_nodes=True)
            f = jax.jit(step, in_shardings=(sspec, bspec, P()),
                        out_shardings=(sspec, {"loss": P()}))
            sh_state, sh_m = f(state0, batch, W)

        np.testing.assert_allclose(float(ref_m["loss"]), float(sh_m["loss"]),
                                   rtol=2e-4)
        for a, b in zip(jax.tree.leaves(ref_state.x),
                        jax.tree.leaves(sh_state.x)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=5e-3)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_gossip_collective_lowering():
    """The gossip einsum over the node axis must lower to cross-node
    collectives (all-gather or all-to-all family), proving the communication
    pattern is real, not a local transpose."""
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import algorithms as alg

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        W = jnp.ones((8, 8)) / 8
        x = jnp.ones((8, 1024))
        with jax.set_mesh(mesh):
            f = jax.jit(lambda W, x: alg.mix(W, x),
                        in_shardings=(P(), P("data", None)),
                        out_shardings=P("data", None))
            txt = f.lower(W, x).compile().as_text()
        has_coll = any(op in txt for op in
                       ("all-gather", "all-to-all", "all-reduce",
                        "collective-permute", "reduce-scatter"))
        print("HAS_COLLECTIVE" if has_coll else "NO_COLLECTIVE")
    """)
    assert "HAS_COLLECTIVE" in out


def test_production_mesh_dryrun_smoke():
    """lower+compile one arch on the real 16x16 production mesh (512 fake
    devices) — the fast proxy for the full deliverable-e sweep."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import lower_one
        r = lower_one("qwen1.5-0.5b", "decode_32k", verbose=False)
        assert r["flops"] > 0
        assert r["collectives"]["total_bytes"] > 0
        r2 = lower_one("qwen1.5-0.5b", "train_4k", multi_pod=True,
                       verbose=False)
        assert r2["flops"] > 0
        print("DRYRUN_OK")
    """)
    assert "DRYRUN_OK" in out


def test_one_peer_gossip_is_sparse_collective():
    """Beyond-paper: a one-peer exponential W lowers to collective-permute /
    cheap collectives, not a full all-gather of all node copies -- checked by
    collective byte volume: one-peer should move far fewer bytes than dense."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from repro.core import algorithms as alg, gossip, topology as topo
        from repro.launch.dryrun import parse_collective_bytes

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.ones((8, 4096))

        def vol(W):
            with jax.set_mesh(mesh):
                f = jax.jit(lambda W, x: alg.mix(W, x),
                            in_shardings=(P(), P("data", None)),
                            out_shardings=P("data", None))
                txt = f.lower(W, x).compile().as_text()
            return parse_collective_bytes(txt)["total_bytes"]

        dense = jnp.ones((8, 8)) / 8
        sparse = jnp.asarray(gossip.schedule_from_topology(
            topo.one_peer_exponential_schedule(8))(0), jnp.float32)
        print(json.dumps({"dense": vol(dense), "sparse": vol(sparse)}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    # GSPMD may or may not specialize; record behaviour, require both lower
    assert data["dense"] > 0
    assert data["sparse"] > 0


def test_hierarchical_mesh_lowers():
    """The beyond-paper hierarchical mesh (node x fsdp x model) lowers a
    training step (2x2x2 on 8 host devices)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import configs
        from repro.dist import sharding as shd, steps as dsteps
        from repro.models import build
        from repro.data import token_stream_for
        from repro.core import gossip
        from repro.launch.mesh import make_hierarchical_mesh

        cfg = configs.get("qwen1.5-0.5b").reduced()
        model = build(cfg)
        n, R = 2, 1
        stream = token_stream_for(cfg, n, R, 2, 32, seed=0)
        sched = gossip.theorem3_weight_schedule(n, 0.5)
        init_state, warm, step = dsteps.make_train_step(model, cfg,
                                                        gamma=0.05, R=R)
        state0 = init_state(jax.random.key(0), n, jnp.float32)
        state0 = warm(state0, stream.batch_at(0))
        batch = stream.batch_at(1)
        W = jnp.asarray(sched.stacked(0, 2 * R))
        mesh = make_hierarchical_mesh(2, 2, 2)
        with jax.set_mesh(mesh):
            sspec = dsteps.TrainState(
                x=shd.param_specs(state0.x, cfg, mesh, stacked_nodes=True),
                h=shd.param_specs(state0.h, cfg, mesh, stacked_nodes=True),
                g_prev=shd.param_specs(state0.g_prev, cfg, mesh,
                                       stacked_nodes=True),
                step=P())
            bspec = shd.batch_specs(batch, mesh, stacked_nodes=True)
            f = jax.jit(step, in_shardings=(sspec, bspec, P()),
                        out_shardings=(sspec, {"loss": P()}))
            _, m = f(state0, batch, W)
        import numpy as np
        assert np.isfinite(float(m["loss"]))
        print("HIER_OK")
    """)
    assert "HIER_OK" in out


def test_planned_mixer_on_mesh_matches_dense_and_uses_ppermute():
    """The auto plan mixer on a sharded mesh: (a) equals the dense matrix
    product for a matching schedule, (b) the static+mesh path lowers the
    matching rounds through collective-permute with less collective volume
    than the dense einsum."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, json, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import algorithms as alg, gossip, topology as topo
        from repro.launch.dryrun import parse_collective_bytes

        n = 8
        sched = gossip.schedule_from_topology(
            topo.one_peer_exponential_schedule(n))
        plan = sched.plan()
        P_ = plan.period
        x = jnp.arange(n * 4096, dtype=jnp.float32).reshape(n, 4096) / 1e3
        Ws = jnp.asarray(sched.stacked(0, P_))
        want = np.asarray(alg.multi_consensus(Ws, x))

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        tensors = jax.tree.map(jnp.asarray, plan.tensors())
        with jax.set_mesh(mesh):
            mixer = alg.make_plan_mixer(plan, mesh=mesh, axis="data")
            assert mixer.dispatch == "static"
            fp = jax.jit(lambda T, x: mixer(T, 0, P_, x),
                         in_shardings=(P(), P("data", None)),
                         out_shardings=P("data", None))
            got = np.asarray(fp(tensors, x))
            vol_plan = parse_collective_bytes(
                fp.lower(tensors, x).compile().as_text())
            fd = jax.jit(lambda Ws, x: alg.multi_consensus(Ws, x),
                         in_shardings=(P(), P("data", None)),
                         out_shardings=P("data", None))
            vol_dense = parse_collective_bytes(
                fd.lower(Ws, x).compile().as_text())
            txt = fp.lower(tensors, x).compile().as_text()
        np.testing.assert_allclose(got, want, atol=1e-4)
        print(json.dumps({"plan": vol_plan["total_bytes"],
                          "dense": vol_dense["total_bytes"],
                          "has_permute": "collective-permute" in txt}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert data["has_permute"], data
    assert data["plan"] < data["dense"], data


def test_one_peer_permute_mix_cheaper_than_dense():
    """one_peer_mix must (a) equal the dense matching W and (b) lower to far
    less collective volume under GSPMD."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, json, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import algorithms as alg, gossip, topology as topo
        from repro.launch.dryrun import parse_collective_bytes

        n = 8
        sched = topo.one_peer_exponential_schedule(n)
        adj = sched(0)
        W = jnp.asarray(gossip.metropolis_weights(adj), jnp.float32)
        peer = jnp.asarray((np.arange(n) ^ 1), jnp.int32)
        x = jnp.arange(n * 4096, dtype=jnp.float32).reshape(n, 4096) / 1e3

        dense = alg.mix(W, x)
        sparse = alg.one_peer_mix(peer, 0.5, x)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse),
                                   atol=1e-4)

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        with jax.set_mesh(mesh):
            fd = jax.jit(lambda W, x: alg.mix(W, x),
                         in_shardings=(P(), P("data", None)),
                         out_shardings=P("data", None))
            vd = parse_collective_bytes(fd.lower(W, x).compile().as_text())
            perm = [(i, int(i) ^ 1) for i in range(n)]
            fs = jax.jit(lambda x: alg.one_peer_mix_ppermute(
                perm, 0.5, x, mesh, "data"),
                         in_shardings=(P("data", None),),
                         out_shardings=P("data", None))
            sp = alg.one_peer_mix_ppermute(perm, 0.5, x, mesh, "data")
            np.testing.assert_allclose(np.asarray(dense), np.asarray(sp),
                                       atol=1e-4)
            vs = parse_collective_bytes(fs.lower(x).compile().as_text())
        print(json.dumps({"dense": vd["total_bytes"],
                          "sparse": vs["total_bytes"]}))
    """)
    data = json.loads(out.strip().splitlines()[-1])
    assert data["sparse"] < data["dense"], data
