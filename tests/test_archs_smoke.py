"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED same-family variant
(<= 4 layers, d_model <= 512, <= 4 experts) and runs one forward + one
train step on CPU, asserting output shapes and no NaNs.  A cache-consistency
test checks that prefill + decode reproduces the teacher-forced forward —
the serve path's correctness oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build, materialize_batch

ARCHS = [
    "internvl2-1b",
    "falcon-mamba-7b",
    "qwen1.5-0.5b",
    "llama4-maverick-400b-a17b",
    "whisper-tiny",
    "granite-moe-3b-a800m",
    "yi-6b",
    "nemotron-4-340b",
    "recurrentgemma-2b",
    "minitron-4b",
]


def _setup(name, batch=2, seq=32):
    cfg = configs.get(name).reduced()
    m = build(cfg)
    params = m.init(jax.random.key(0))
    data = materialize_batch(cfg, batch, seq, jax.random.key(1), jnp.float32)
    return cfg, m, params, data


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_registered(name):
    cfg = configs.get(name)
    assert cfg.source, "config must cite its source"
    spec = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151_655),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65_024),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151_936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202_048),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51_865),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
        "yi-6b": (32, 4096, 32, 4, 11_008, 64_000),
        "nemotron-4-340b": (96, 18_432, 96, 8, 73_728, 256_000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256_000),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (got, spec)


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    r = configs.get(name).reduced()
    assert r.d_model <= 512
    assert r.num_layers <= 4
    assert r.num_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_train_step(name):
    cfg, m, params, data = _setup(name)
    loss, grads = jax.value_and_grad(m.train_loss)(params, data)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    # one SGD step
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                              params, grads)
    loss2 = m.train_loss(new_params, data)
    assert np.isfinite(float(loss2))
    assert float(loss2) < float(loss) + 0.5  # a step should not explode
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(leaf).any()), f"{name}: NaN grad"


@pytest.mark.parametrize("name", ARCHS)
def test_cache_consistency_decode_matches_forward(name):
    """Teacher-forced logits at position t must match prefill(t-1) + decode."""
    cfg, m, params, data = _setup(name, batch=1, seq=24)
    if cfg.arch_type == "audio":
        from repro.models import encdec
        tokens, frames = data["tokens"], data["frames"]
        full_logits, _, _ = encdec.forward(params, cfg, tokens, frames,
                                           mode="train")
        cache = m.init_cache(1, 32, jnp.float32)
        pre = {"tokens": tokens[:, :8], "frames": frames}
        logits, cache = m.prefill(params, pre, cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, 7]),
                                   rtol=5e-2, atol=5e-3)
        for t in range(8, 12):
            step_logits, cache = m.decode_step(params, tokens[:, t:t + 1],
                                               cache, jnp.int32(t))
            np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                       np.asarray(full_logits[:, t]),
                                       rtol=5e-2, atol=5e-3)
        return

    from repro.models import transformer
    tokens = data["tokens"]
    prefix = data.get("prefix_embeds")
    full_logits, _, _ = transformer.forward(params, cfg, tokens,
                                            prefix_embeds=prefix, mode="train")
    P = 0 if prefix is None else prefix.shape[1]
    cache = m.init_cache(1, 48, jnp.float32)
    cut = 8
    pre = {"tokens": tokens[:, :cut]}
    if prefix is not None:
        pre["prefix_embeds"] = prefix
    logits, cache = m.prefill(params, pre, cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, P + cut - 1]),
                               rtol=5e-2, atol=5e-3)
    for t in range(cut, cut + 4):
        step_logits, cache = m.decode_step(params, tokens[:, t:t + 1], cache,
                                           jnp.int32(P + t))
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full_logits[:, P + t]),
                                   rtol=5e-2, atol=5e-3)
