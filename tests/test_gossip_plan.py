"""Structure-aware gossip planning tests: every lowering the planner can
pick (sun / matching / complete / empty / dense) must agree with the dense
``mix(W, ·)`` path, and the auto dispatcher must actually pick the cheap
lowering on the structured schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import algorithms as alg, gossip, topology as topo
from repro.exp import make_weight_schedule

PLANNABLE = ["sun", "ring", "one-peer-exp", "static-exp", "federated",
             "complete", "random-matching", "resampled-matching",
             "erdos-renyi"]

# the acceptance map: what the planner must select per schedule family
EXPECTED_KINDS = {
    "sun": {"sun"},
    "one-peer-exp": {"matching"},
    "federated": {"empty", "complete"},
    "complete": {"complete"},
    "random-matching": {"matching"},
    "resampled-matching": {"matching"},
    "ring": {"dense"},
    "static-exp": {"dense"},
}


def _sched(kind, n=8, beta=0.75):
    return make_weight_schedule(kind, n, beta, horizon=12, seed=0)


def _tree(n, seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (n, 5)),
            "b": {"c": jax.random.normal(jax.random.fold_in(k, 1), (n, 3, 2))}}


def _max_err(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("kind", sorted(EXPECTED_KINDS))
def test_auto_planner_selects_structured_lowering(kind):
    plan = _sched(kind).plan()
    assert set(plan.kinds) == EXPECTED_KINDS[kind], plan.kinds


def test_plan_validates_structured_equals_dense():
    for kind in PLANNABLE:
        sched = _sched(kind)
        plan = sched.plan(validate=True)  # raises on any lowering mismatch
        for t, rd in enumerate(plan.rounds):
            np.testing.assert_allclose(rd.as_dense(), sched(t), atol=1e-8)


@pytest.mark.parametrize("kind", PLANNABLE)
def test_planned_multi_consensus_matches_dense(kind):
    """Full-period planned mixing == dense multi_consensus, both dispatch
    modes, on every schedule make_weight_schedule can produce."""
    sched = _sched(kind)
    plan = sched.plan()
    P = plan.period
    tree = _tree(sched.n)
    want = alg.multi_consensus(jnp.asarray(sched.stacked(0, P)), tree)
    tensors = jax.tree.map(jnp.asarray, plan.tensors())

    static_mix = alg.make_plan_mixer(plan, mode="static")
    assert _max_err(want, static_mix(tensors, 0, P, tree)) < 1e-5
    # offset start phase: rounds [1, 1+P) wrap the period
    want_off = alg.multi_consensus(jnp.asarray(sched.stacked(1, P)), tree)
    assert _max_err(want_off, static_mix(tensors, 1, P, tree)) < 1e-5

    if plan.dispatch == "dynamic":
        dyn_mix = alg.make_plan_mixer(plan)
        assert dyn_mix.dispatch == "dynamic"
        f = jax.jit(lambda T, t, tr: dyn_mix(T, t, P, tr))
        assert _max_err(want, f(tensors, jnp.int32(0), tree)) < 1e-5
        assert _max_err(want_off, f(tensors, jnp.int32(1), tree)) < 1e-5


def test_dynamic_dispatch_rejects_mixed_plans():
    plan = _sched("federated").plan()
    assert plan.dispatch == "static"
    with pytest.raises(ValueError):
        alg.make_plan_mixer(plan, mode="dynamic")


def test_structured_primitives_match_dense_mix():
    """sun_mix / one_peer_mix / complete_mix == mix(W, ·) on their exact
    weight matrices (the lowering identities the planner relies on)."""
    n = 8
    tree = _tree(n)
    # sun: Theorem 3 matrix
    ws = gossip.theorem3_weight_schedule(n, 0.6)
    rd = ws.plan().rounds[0]
    got = alg.sun_mix(jnp.asarray(rd.center_mask), rd.delta, tree)
    assert _max_err(alg.mix(jnp.asarray(ws(0), jnp.float32), tree), got) < 1e-5
    # matching: Metropolis on a one-peer graph (w = 1/2 each)
    wm = gossip.schedule_from_topology(topo.one_peer_exponential_schedule(n))
    rdm = wm.plan().rounds[0]
    got = alg.one_peer_mix(jnp.asarray(rdm.perm), jnp.asarray(rdm.w_peer), tree)
    assert _max_err(alg.mix(jnp.asarray(wm(0), jnp.float32), tree), got) < 1e-5
    # complete: W = (1-a) I + a 11^T/n
    W = 0.3 * np.eye(n) + 0.7 * np.ones((n, n)) / n
    rdc = gossip.plan_round(W)
    assert rdc.kind == "complete"
    got = alg.complete_mix(rdc.avg_weight, tree)
    assert _max_err(alg.mix(jnp.asarray(W, jnp.float32), tree), got) < 1e-5


def test_plan_round_falls_back_to_dense_on_nonuniform_weights():
    """A sun-shaped sparsity pattern with non-uniform edge weights is NOT
    the Laplacian form sun_mix computes — the planner must go dense."""
    n = 6
    adj = topo.sun_shaped_graph(n, [0, 1])
    W = gossip.metropolis_weights(adj)
    W2 = W.copy()
    # symmetric cycle perturbation over sun edges 0-2, 2-1, 1-3, 3-0: row
    # and column sums stay 1, sparsity stays sun, uniformity breaks
    eps = 0.01
    for i, j, s in [(0, 2, +eps), (2, 1, -eps), (1, 3, +eps), (3, 0, -eps)]:
        W2[i, j] += s
        W2[j, i] += s
    gossip.check_assumption3(W2, adj)
    assert gossip.plan_round(W2).kind == "dense"
    assert gossip.plan_round(W).kind == "sun"


def test_resampled_matching_is_nonperiodic_and_seed_streamed():
    sch = topo.resampled_matching_schedule(12, seed=7)
    assert sch.period is None
    assert np.array_equal(sch(5), sch(5))          # deterministic in t
    adjs = [sch(t) for t in range(8)]
    assert any(not np.array_equal(adjs[0], a) for a in adjs[1:])
    ws = gossip.schedule_from_topology(sch, horizon=8)
    assert ws.period == 8
    assert set(ws.plan().kinds) == {"matching"}
    with pytest.raises(ValueError):
        gossip.schedule_from_topology(sch)         # horizon required


def test_erdos_renyi_schedule_varies_and_mixes():
    sch = topo.erdos_renyi_schedule(12, 0.5, period=6, seed=1)
    assert sch.period == 6
    assert any(not np.array_equal(sch(0), sch(t)) for t in range(1, 6))
    ws = gossip.schedule_from_topology(sch)
    for t in range(ws.period):
        gossip.check_assumption3(ws(t), sch(t))
    assert gossip.consensus_contraction(ws, ws.period) < 1.0


@settings(max_examples=15, deadline=None)
@given(kind=st.sampled_from(PLANNABLE), n_pow=st.integers(2, 4),
       seed=st.integers(0, 50))
def test_property_planned_equals_dense_any_schedule(kind, n_pow, seed):
    """Property: for any schedule family x (power-of-two) size x seed, one
    planned period == the dense matrix product applied to random state."""
    n = 2 ** n_pow
    sched = make_weight_schedule(kind, n, 0.75, horizon=10, seed=seed)
    plan = sched.plan()
    tree = _tree(n, seed)
    want = alg.multi_consensus(jnp.asarray(sched.stacked(0, plan.period)), tree)
    mixer = alg.make_plan_mixer(plan, mode="static")
    got = mixer(jax.tree.map(jnp.asarray, plan.tensors()), 0, plan.period, tree)
    assert _max_err(want, got) < 1e-5


# ---------------------------------------------------------------------------
# End-to-end: the auto dispatcher through the training driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["sun", "federated", "one-peer-exp"])
def test_train_driver_auto_matches_dense_losses(topology):
    """Acceptance: step-for-step losses of --gossip-impl auto == dense on a
    2-step reduced run (same seed, same schedule)."""
    from repro.launch.train import main as train_main
    base = ["--arch", "qwen1.5-0.5b", "--preset", "reduced", "--steps", "2",
            "--nodes", "4", "--batch", "1", "--seq", "16",
            "--topology", topology]
    dense = train_main(base + ["--gossip-impl", "dense"])
    auto = train_main(base + ["--gossip-impl", "auto"])
    assert len(dense) == len(auto) == 2
    for hd, ha in zip(dense, auto):
        np.testing.assert_allclose(hd["loss"], ha["loss"], rtol=2e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(hd["consensus"], ha["consensus"],
                                   atol=1e-3)


def test_train_driver_d2_end_to_end():
    """D^2 is runnable through the CLI (extra Table-1-family baseline)."""
    from repro.launch.train import main as train_main
    hist = train_main(["--arch", "qwen1.5-0.5b", "--preset", "reduced",
                       "--steps", "3", "--nodes", "4", "--algo", "d2",
                       "--gamma", "0.05", "--batch", "1", "--seq", "16",
                       "--topology", "sun", "--gossip-impl", "auto"])
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_dist_steps_d2_matches_core_reference():
    """dist.steps d2 (clip disabled) tracks the core reference update on a
    tiny quadratic-like model state: one step reduces to DSGD."""
    from repro import configs
    from repro.dist import steps as dsteps
    from repro.models import build
    from repro.data import token_stream_for

    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    n = 4
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    stream = token_stream_for(cfg, n, 1, 2, 16, seed=0)
    gamma = 0.05
    init_d2, warm_d2, step_d2 = dsteps.make_train_step(
        model, cfg, algo="d2", gamma=gamma, R=1, clip=None)
    init_sg, warm_sg, step_sg = dsteps.make_train_step(
        model, cfg, algo="dsgd", gamma=gamma, R=1, clip=None)
    s_d2 = warm_d2(init_d2(jax.random.key(0), n, jnp.float32),
                   stream.batch_at(0))
    s_sg = init_sg(jax.random.key(0), n, jnp.float32)
    batch = stream.batch_at(1)
    W = jnp.asarray(sched.stacked(0, 1))
    out_d2, m_d2 = jax.jit(step_d2)(s_d2, batch, W)
    out_sg, m_sg = jax.jit(step_sg)(s_sg, batch, W)
    np.testing.assert_allclose(float(m_d2["loss"]), float(m_sg["loss"]),
                               rtol=1e-5)
    # warm start makes the first D^2 update exactly a DSGD step
    assert _max_err(out_d2.x, out_sg.x) < 1e-5
