"""Compressed gossip (ISSUE 7): quantization parity, error-feedback
residual threading, warmup gating, and bytes accounting.

The quantization math exists once (kernels/ref.py); everything here pins
the layers that consume it to that single source: the fused Pallas kernel
(any legal block size), the generic compressed mixer the host and dist
runtimes wrap around their per-round mixers, the engine's residual
threading, and the telemetry byte accounting the manifests report.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import algorithms as alg, compress, engine, gossip
from repro.dist import collectives as coll, steps as dsteps
from repro.kernels import ops, ref

SCHEMES = ("sign", "int8")


def _ws(n, rounds, beta=0.6, seed=0):
    sched = gossip.theorem3_weight_schedule(n, beta)
    return jnp.asarray(sched.stacked(seed, rounds), jnp.float32)


def _tree_err(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# 1. Fused kernel == kernels/ref.py, property-tested across schemes
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), scheme_i=st.integers(0, 1),
       ef=st.booleans(), rounds=st.integers(1, 3),
       group_i=st.integers(0, 1), bd_i=st.integers(0, 2))
def test_property_fused_kernel_matches_ref(seed, scheme_i, ef, rounds,
                                           group_i, bd_i):
    scheme = SCHEMES[scheme_i]
    group = (64, 128)[group_i]
    n, D = 8, 512
    block_d = (group, 256, D)[bd_i]
    k = jax.random.key(seed)
    x = jax.random.normal(k, (n, D))
    res = 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (n, D))
    ws = _ws(n, rounds, seed=seed % 4)
    o_ref, r_ref = ref.quantized_gossip_mix_ref(
        ws, x, res, scheme=scheme, group=group, error_feedback=ef)
    o_k, r_k = ops.quantized_gossip_mix(
        ws, x, res, scheme=scheme, group=group, error_feedback=ef,
        use_pallas=True, block_d=block_d)
    np.testing.assert_allclose(o_k, o_ref, atol=1e-5)
    np.testing.assert_allclose(r_k, r_ref, atol=1e-5)


def test_quantize_int8_zero_group_guard():
    """An all-zero group must dequantize to zeros (no 0/0 NaN) and carry a
    zero residual for every scheme."""
    buf = jnp.zeros((2, 64))
    for scheme in SCHEMES:
        deq, err = ref.quantize_dequantize_ref(buf, scheme=scheme, group=32)
        assert not np.any(np.isnan(deq)) and not np.any(np.isnan(err))
        np.testing.assert_array_equal(deq, 0.0)
        np.testing.assert_array_equal(err, 0.0)


def test_payload_bytes_formula():
    # none = full f32; sign = 1 bit/entry + one f32 scale per group;
    # int8 = 1 byte/entry + one f32 scale per group
    assert compress.payload_bytes(1000, "none") == 4000
    assert compress.payload_bytes(1000, "sign") == 125 + 4 * 4
    assert compress.payload_bytes(1000, "int8") == 1000 + 4 * 4
    assert compress.payload_bytes(1000, "sign", group=1000) == 125 + 4
    with pytest.raises(ValueError):
        compress.payload_bytes(10, "fp4")


def test_compression_config_validates():
    with pytest.raises(ValueError):
        compress.CompressionConfig(scheme="none")
    with pytest.raises(ValueError):
        compress.CompressionConfig(scheme="sign", group=0)
    with pytest.raises(ValueError):
        compress.CompressionConfig(scheme="int8", warmup=-1)


# ---------------------------------------------------------------------------
# 2. flatten_grouped: group-aligned padding is lossless and exact
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), group=st.sampled_from([4, 8, 32]))
def test_property_flatten_grouped_roundtrip(seed, group):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    tree = {"a": jnp.asarray(rng.normal(size=(n, int(rng.integers(1, 40))))),
            "b": {"c": jnp.asarray(
                rng.normal(size=(n, 3, int(rng.integers(1, 7)))),
                dtype=jnp.bfloat16)},
            "d": jnp.asarray(rng.normal(size=(n,)))}
    mat, meta = compress.flatten_grouped(tree, group)
    assert mat.shape[1] % group == 0
    back = compress.unflatten_grouped(mat, meta)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_zero_padding_is_quantization_fixed_point():
    """Leaf padding columns stay exactly zero through quantize / mix /
    residual, so per-leaf group alignment never leaks into real entries."""
    n, size, group = 4, 10, 8  # pads 10 -> 16
    tree = {"a": jax.random.normal(jax.random.key(0), (n, size))}
    mat, _ = compress.flatten_grouped(tree, group)
    ws = _ws(n, 2)
    out, res = ref.quantized_gossip_mix_ref(ws, mat, jnp.zeros_like(mat),
                                            scheme="sign", group=group)
    np.testing.assert_array_equal(np.asarray(out[:, size:]), 0.0)
    np.testing.assert_array_equal(np.asarray(res[:, size:]), 0.0)


# ---------------------------------------------------------------------------
# 3. One implementation across runtimes: dense == pallas == auto plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("algo", ["mc_dsgt", "dsgd"])
def test_dist_dense_equals_pallas_equals_auto(algo, scheme):
    from test_engine import ToyModel, _toy_batch

    model = ToyModel()
    n, R = 8, 2 if algo == "mc_dsgt" else 1
    cfg = compress.CompressionConfig(scheme=scheme, group=4)
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    plan = sched.plan()
    batch0 = _toy_batch(n, R, 3, model.d, seed=0)
    batch1 = _toy_batch(n, R, 3, model.d, seed=1)
    wps = engine.make_rule(algo, gamma=0.1, R=R).weights_per_step
    Ws = jnp.asarray(sched.stacked(0, max(wps, 1)))

    states = {}
    for impl in ("dense", "pallas", "auto"):
        init, warm, step = dsteps.make_train_step(
            model, None, algo=algo, gamma=0.1, R=R, gossip_impl=impl,
            compression=cfg, pallas_block_d=8,
            plan=(plan if impl == "auto" else None))
        s = warm(init(jax.random.key(0), n, jnp.float32), batch0)
        assert s.res is not None
        if impl == "auto":
            tensors = jax.tree.map(jnp.asarray, plan.tensors())
            jstep = (jax.jit(step, static_argnums=3)
                     if step.gossip_dispatch == "static" else jax.jit(step))
            for t in range(2):
                s, _ = jstep(s, batch1, tensors, t * wps)
        else:
            for _ in range(2):
                s, _ = jax.jit(step)(s, batch1, Ws)
        states[impl] = s

    # step 2 of dense/pallas reuses W(0); auto follows the true schedule, so
    # compare everyone after step 1 ... except pallas/dense, comparable at 2
    assert _tree_err(states["dense"].x, states["pallas"].x) < 1e-5
    assert _tree_err(states["dense"].res[0], states["pallas"].res[0]) < 1e-5


@pytest.mark.parametrize("scheme", SCHEMES)
def test_host_dense_equals_plan_equals_dist(scheme):
    """from_rule (stacked einsum), plan_step (structured lowering), and the
    dist fused path all produce the same compressed trajectory."""
    n, d, R = 8, 12, 2
    cfg = compress.CompressionConfig(scheme=scheme, group=4)
    rule = engine.make_rule("mc_dsgt", gamma=0.1, R=R, compression=cfg)
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    plan = sched.plan()
    wps = rule.weights_per_step

    A = jax.random.normal(jax.random.key(1), (n, 5, d))
    b = jax.random.normal(jax.random.key(2), (n, 5))

    def grad_fn(x, key):
        def per(xi, Ai, bi):
            r = Ai @ xi - bi
            return 2 * Ai.T @ r / r.shape[0]
        return jax.vmap(per)(x, A, b)

    runner = alg.from_rule(rule)
    x0 = jax.random.normal(jax.random.key(0), (n, d))

    sd = runner.warm(runner.init(x0), grad_fn, jax.random.key(9))
    sp = sd
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    pstep = alg.plan_step(runner, plan)
    for t in range(3):
        Ws = jnp.asarray(sched.stacked(t * wps, wps))
        sd = runner.step(sd, grad_fn, Ws, jax.random.key(t))
        sp = pstep(sp, grad_fn, tensors, t * wps, jax.random.key(t))
    assert _tree_err(sd.x, sp.x) < 1e-5
    assert _tree_err(sd.res[0], sp.res[0]) < 1e-5
    assert sd.res[1] is not None  # tracker stream carries its own residual


def test_fused_quantized_consensus_matches_generic_mixer():
    """dist.collectives.fused_quantized_consensus (the Pallas window) ==
    core.compress.make_compressed_mixer over the same per-round mixer, on a
    ragged pytree whose leaves need group padding."""
    n, R = 8, 3
    cfg = compress.CompressionConfig(scheme="sign", group=8)
    ws = _ws(n, R)
    tree = {"a": jax.random.normal(jax.random.key(0), (n, 50)),
            "b": jax.random.normal(jax.random.key(1), (n, 3, 5))}
    res = jax.tree.map(jnp.zeros_like, tree)

    cmix = compress.make_compressed_mixer(lambda idx, m: ws[idx] @ m, cfg)
    want, wres = cmix(0, R, tree, res, None)
    got, gres = coll.fused_quantized_consensus(ws, tree, res, cfg=cfg,
                                               block_d=16)
    assert _tree_err(want, got) < 1e-5
    assert _tree_err(wres, gres) < 1e-5


# ---------------------------------------------------------------------------
# 4. Engine semantics: warmup gate, EF off, residual lifecycle
# ---------------------------------------------------------------------------

def test_warmup_equals_uncompressed_until_activation():
    from test_engine import ToyModel, _toy_batch

    model = ToyModel()
    n, R, warmup = 8, 2, 3
    cfg = compress.CompressionConfig(scheme="sign", group=4, warmup=warmup)
    Ws = jnp.asarray(_ws(n, 4))
    batch0 = _toy_batch(n, R, 3, model.d, seed=0)
    batch1 = _toy_batch(n, R, 3, model.d, seed=1)

    def make(comp):
        init, warm, step = dsteps.make_train_step(
            model, None, algo="mc_dsgt", gamma=0.1, R=R, compression=comp)
        return warm(init(jax.random.key(0), n, jnp.float32), batch0), \
            jax.jit(step)

    sc, cstep = make(cfg)
    sp, pstep = make(None)
    for k in range(warmup + 1):
        sc, _ = cstep(sc, batch1, Ws)
        sp, _ = pstep(sp, batch1, Ws)
        if k < warmup:  # still warming up: identical to plain, zero residual
            assert _tree_err(sc.x, sp.x) == 0.0
            assert float(sum(jnp.sum(jnp.abs(l))
                             for l in jax.tree.leaves(sc.res[0]))) == 0.0
        else:  # the scheme activated exactly at k == warmup
            assert _tree_err(sc.x, sp.x) > 0.0
            assert float(sum(jnp.sum(jnp.abs(l))
                             for l in jax.tree.leaves(sc.res[0]))) > 0.0


def test_error_feedback_off_keeps_residual_zero():
    n, D, R = 8, 64, 2
    ws = _ws(n, R)
    x = jax.random.normal(jax.random.key(0), (n, D))
    out, res = ref.quantized_gossip_mix_ref(ws, x, jnp.zeros_like(x),
                                            scheme="sign", group=8,
                                            error_feedback=False)
    np.testing.assert_array_equal(np.asarray(res), 0.0)
    # and EF genuinely changes the mixed output given a nonzero residual
    out_ef, res_ef = ref.quantized_gossip_mix_ref(
        ws, x, jnp.zeros_like(x), scheme="sign", group=8,
        error_feedback=True)
    assert float(jnp.abs(res_ef).max()) > 0.0


def test_engine_requires_cmix_and_residuals():
    cfg = compress.CompressionConfig(scheme="sign")
    rule = engine.make_rule("dsgd", gamma=0.1, compression=cfg)
    x0 = {"w": jnp.ones((4, 8))}
    st_ok = engine.init_state(rule, x0)
    assert st_ok.res is not None
    ops_nocmix = engine.EngineOps(
        mix=lambda off, r, t: t, grad=lambda x: (None, x),
        local_update=lambda g, s: (g, s), cast_aux=lambda t: t)
    with pytest.raises(ValueError):
        engine.step(rule, st_ok, ops_nocmix)


# ---------------------------------------------------------------------------
# 5. Spec / registry / bytes telemetry
# ---------------------------------------------------------------------------

def test_spec_compression_roundtrip_and_registry():
    from repro import exp

    spec = exp.from_dict({"compression": {"scheme": "int8", "group": 128,
                                          "warmup": 5,
                                          "error_feedback": False}})
    assert exp.from_json(exp.to_json(spec)) == spec
    cfg = exp.build_compression(spec.compression)
    assert cfg == compress.CompressionConfig(
        scheme="int8", error_feedback=False, warmup=5, group=128)
    assert exp.build_compression(exp.CompressionSpec()) is None
    with pytest.raises(KeyError):
        exp.from_dict({"compression": {"codec": "sign"}})
    with pytest.raises(ValueError):
        exp.build(exp.from_dict({"compression": {"scheme": "fp4"}}))


def test_telemetry_bytes_accounting():
    """bytes/bytes_total count active senders per realized round at the
    scheme's wire format — full f32 during warmup, compressed after — and
    accumulate across every step regardless of the log cadence."""
    from repro.core import topology
    from repro.sim.telemetry import TelemetryRecorder

    n, d, wps = 4, 32, 1
    # federated(local_steps=2): rounds 0,1 empty; round 2 complete (n
    # senders); period 3.  warmup=3 puts the first complete round (step 2)
    # at full precision and the second (step 5) under the scheme.
    sched = gossip.schedule_from_topology(topology.federated_schedule(n, 2))
    cfg = compress.CompressionConfig(scheme="sign", group=8, warmup=3)

    class _S:
        x = jnp.ones((n, d))

    tl = TelemetryRecorder(sched, wps=wps, every=2, compression=cfg)
    full = compress.payload_bytes(d, "none")
    comp = compress.payload_bytes(d, "sign", 8)
    got = []
    for k in range(6):
        entry = tl.record(k, (k + 1) * wps, _S(), None, 0.0)
        if k % 2 == 0:  # log cadence gates the entry, not the accounting
            assert entry is not None and "bytes" in entry \
                and entry["bytes_total"] == tl.bytes_total
        got.append(None if entry is None else entry["bytes"])
    assert got[0] == 0 and got[4] == 0  # empty local rounds send nothing
    assert got[2] == n * full           # complete round inside warmup
    assert tl.bytes_total == n * (full + comp)
    uncompressed = TelemetryRecorder(sched, wps=wps, every=1,
                                     compression=None)
    for k in range(6):
        uncompressed.record(k, (k + 1) * wps, _S(), None, 0.0)
    assert uncompressed.bytes_total == n * full * 2
    assert uncompressed.bytes_total > tl.bytes_total


def test_manifest_reports_bytes_per_round_for_every_scheme():
    from repro import exp

    for scheme in ("none", "sign", "int8"):
        spec = exp.from_dict({
            "model": {"kind": "logreg", "d": 64, "m": 8},
            "compression": ({"scheme": scheme} if scheme != "none" else {}),
            "run": {"steps": 1, "nodes": 4}})
        built = exp.build(spec)
        rc = built.realized["compression"]
        assert rc["scheme"] == scheme
        assert rc["state_dim"] == 64
        assert rc["baseline_bytes_per_round"] == 4 * 64
        want = compress.payload_bytes(64, scheme, 256)
        assert rc["bytes_per_round"] == want
        if scheme == "none":
            assert rc["bytes_per_round"] == rc["baseline_bytes_per_round"]
