"""Sparse scenario engine: edge-list rounds/plans, the Pallas segment-sum
mixer, sampled-client topologies, O(edges) fault realization, and the
sparse telemetry proxies — pinned against the dense stack at small n.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exp, sparse
from repro.core import algorithms as alg, driver, engine, gossip
from repro.core import topology as topo
from repro.data import logreg_dataset, logreg_loss_and_grad
from repro.kernels import ops as kops
from repro.sim import channel as sim_channel, faults as sim_faults, \
    hashrand, telemetry as sim_telemetry


def _chain_dense(mats, x):
    for W in mats:
        x = W @ x
    return x


# ---------------------------------------------------------------------------
# 1. Representation: dense <-> edge-list round trips are bit-exact
# ---------------------------------------------------------------------------

def _dense_schedules_64():
    return {
        "matching": gossip.schedule_from_topology(
            topo.one_peer_exponential_schedule(64)),
        "sun": gossip.theorem3_weight_schedule(64, 0.75),
    }


@pytest.mark.parametrize("family", ["matching", "sun"])
def test_round_from_dense_bit_exact(family):
    ws = _dense_schedules_64()[family]
    for t in range(min(ws.period, 6)):
        W = np.asarray(ws(t), np.float64)
        rd = sparse.round_from_dense(W)
        rd.check()
        assert np.array_equal(rd.as_dense(), W)  # pinned diag: bit-exact


def test_sampled_round_bit_exact_and_deterministic():
    sched = sparse.SampledMobilitySchedule(64, sample_k=16, seed=3)
    for t in (0, 5, 11):
        rd, rd2 = sched.round(t), sched.round(t)
        assert np.array_equal(rd.src, rd2.src)
        assert np.array_equal(rd.w, rd2.w)  # (seed, t)-pure
        rd.check()
        W = rd.as_dense()
        gossip.check_assumption3(W)
        assert np.array_equal(sparse.round_from_dense(W).as_dense(), W)


def test_plan_as_dense_reconstructs_dense_plan():
    ws = gossip.theorem3_weight_schedule(64, 0.75)
    plan = sparse.from_weight_schedule(ws).plan()
    dense_plan = plan.as_dense(validate=True)
    assert dense_plan.period == ws.period
    for r in range(ws.period):
        assert np.array_equal(dense_plan.rounds[r].W, np.asarray(ws(r)))


def test_schedule_duck_type_surface():
    sws = sparse.sampled_weight_schedule(64, 8, horizon=6, seed=1)
    assert sws.is_sparse and sws.n == 64 and sws.period == 6
    assert np.array_equal(sws(2), sws.round(2).as_dense())
    assert sws.structure(2).kind in ("empty", "matching", "dense")
    assert sws.stacked(0, 3).shape == (3, 64, 64)
    assert sws.edges_per_round.shape == (6,)
    assert (sws.senders_per_round <= 8).all()


def test_dense_guard_refuses_materialization():
    sws = sparse.sampled_weight_schedule(20_000, 4, horizon=2, seed=0)
    with pytest.raises(ValueError, match="gossip_impl='auto'"):
        sws.stacked(0, 1)
    with pytest.raises(ValueError, match="edge-list"):
        sws.round(0).as_dense()


# ---------------------------------------------------------------------------
# 2. Mixing: scatter path, Pallas kernel, and the core "sparse" round kind
# ---------------------------------------------------------------------------

def test_sparse_gossip_mix_matches_dense():
    sched = sparse.SampledMobilitySchedule(64, sample_k=24, seed=5)
    rd = sched.round(2)
    assert rd.edges > 0
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 16)).astype(np.float32)
    want = rd.as_dense() @ x

    assert np.allclose(rd.apply(x), want, atol=1e-12)  # numpy host path

    plan = sparse.SparseGossipPlan.from_rounds([rd])
    tt = plan.tensors()
    args = (jnp.asarray(x), jnp.asarray(tt["esrc"][0]),
            jnp.asarray(tt["edst"][0]), jnp.asarray(tt["ew"][0]),
            jnp.asarray(tt["seg"][0]), jnp.asarray(tt["slots"][0]))
    got_ref = kops.sparse_gossip_mix(*args, use_pallas=False)
    got_pal = kops.sparse_gossip_mix(*args, use_pallas=True)
    assert np.allclose(got_ref, want, atol=1e-5)
    assert np.allclose(got_pal, want, atol=1e-5)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_plan_mixer_matches_dense_window(use_pallas):
    sws = sparse.sampled_weight_schedule(64, 16, horizon=6, seed=2)
    plan = sws.plan()
    mixer = plan.make_mixer(use_pallas=use_pallas)
    rng = np.random.default_rng(1)
    tree = {"a": jnp.asarray(rng.standard_normal((64, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((64, 3, 4)), jnp.float32)}
    tensors = {k: jnp.asarray(v) for k, v in plan.tensors().items()}
    out = mixer(tensors, 0, 6, tree)
    mats = [sws(t) for t in range(6)]
    for k in tree:
        want = _chain_dense(mats, np.asarray(tree[k]).reshape(64, -1))
        assert np.allclose(np.asarray(out[k]).reshape(64, -1), want,
                           atol=5e-5), k


def test_core_plan_sparse_round_kind():
    """The dense planner's edge-list fallback: forced at small n, automatic
    above the node/density thresholds, dense below them (bit-exact)."""
    W = sparse.SampledMobilitySchedule(64, sample_k=24, seed=5) \
        .round(2).as_dense()
    assert gossip.plan_round(W).kind == "dense"        # auto: n < 128
    forced = gossip.plan_round(W, sparse=True)
    assert forced.kind == "sparse"
    assert np.allclose(forced.as_dense(), W, atol=1e-12)

    big = sparse.SampledMobilitySchedule(256, sample_k=24, seed=5) \
        .round(2).as_dense()
    assert gossip.plan_round(big).kind == "sparse"     # auto: past threshold
    assert gossip.plan_round(big, sparse=False).kind == "dense"

    # structured rounds keep their structured lowering even when forced
    sun = gossip.theorem3_weight_schedule(64, 0.75)(1)
    assert gossip.plan_round(np.asarray(sun), sparse=True).kind != "sparse"


def test_core_plan_sparse_mixing_matches_dense():
    """A core GossipPlan holding 'sparse'-kind rounds mixes identically to
    the dense plan of the same window (the _apply_uniform scan branch)."""
    sched = sparse.SampledMobilitySchedule(64, sample_k=24, seed=7)
    mats = [sched.round(t).as_dense() for t in range(4)]
    ws = gossip.WeightSchedule(
        tuple(mats), tuple(topo.classify_adjacency(np.abs(M) > 1e-12)
                           for M in mats))
    plan_sparse = ws.plan(0, 4, sparse=True)
    plan_dense = ws.plan(0, 4, sparse=False)
    assert set(plan_sparse.kinds) == {"sparse"}
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    ten_s = {k: jnp.asarray(v) for k, v in plan_sparse.tensors().items()}
    ten_d = {k: jnp.asarray(v) for k, v in plan_dense.tensors().items()}
    mix_s = alg.make_plan_mixer(plan_sparse)
    mix_d = alg.make_plan_mixer(plan_dense)
    got_s = mix_s(ten_s, 0, 4, x)
    got_d = mix_d(ten_d, 0, 4, x)
    assert np.allclose(got_s, got_d, atol=5e-5)
    assert np.allclose(got_s, _chain_dense(mats, np.asarray(x)), atol=5e-5)


# ---------------------------------------------------------------------------
# 3. Fault realization on edge lists
# ---------------------------------------------------------------------------

def test_repaired_sampled_rounds_satisfy_assumption3():
    ideal = sparse.sampled_weight_schedule(64, 16, horizon=8, seed=4)
    models = [sim_channel.BernoulliDropChannel(0.3, seed=11),
              sim_faults.NodeChurn(0.1, seed=12)]
    real = sparse.realize_sparse_schedule(ideal, models)
    assert real.period == ideal.period
    dropped = 0
    for t in range(real.period):
        rd = real.round(t)
        rd.check()
        gossip.check_assumption3(rd.as_dense())
        dropped += ideal.round(t).edges - rd.edges
    assert dropped > 0  # the channel actually removed edges


def test_edge_masks_deterministic_symmetric_diagonal_safe():
    src = np.repeat(np.arange(16), 16).astype(np.int64)
    dst = np.tile(np.arange(16), 16).astype(np.int64)
    models = [sim_channel.BernoulliDropChannel(0.4, seed=1),
              sim_channel.GilbertElliottChannel(0.3, seed=2),
              sim_faults.NodeChurn(0.3, seed=3),
              sim_faults.StragglerInjection(0.3, seed=4)]
    for m in models:
        a = m.edge_mask(5, src, dst)
        b = m.edge_mask(5, src, dst)
        assert np.array_equal(a, b), type(m).__name__      # (seed, t)-pure
        flipped = m.edge_mask(5, dst, src)
        assert np.array_equal(a, flipped), type(m).__name__  # symmetric
        assert a[src == dst].all(), type(m).__name__  # never drops self
        assert a.any() and not a[src != dst].all(), type(m).__name__
    comb = sim_faults.combined_edge_mask(models, 5, src, dst)
    every = np.logical_and.reduce([m.edge_mask(5, src, dst)
                                   for m in models])
    assert np.array_equal(comb, every | (src == dst))


def test_bernoulli_edge_mask_rate():
    n = 400
    lo, hi = np.triu_indices(n, k=1)
    ch = sim_channel.BernoulliDropChannel(0.25, seed=9)
    keep = np.mean([ch.edge_mask(t, lo, hi).mean() for t in range(6)])
    assert abs(keep - 0.75) < 0.01


def test_hashrand_streams():
    u = hashrand.counter_uniform(7, 0xB1, np.arange(4096), 3)
    assert np.array_equal(
        u, hashrand.counter_uniform(7, 0xB1, np.arange(4096), 3))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.02
    assert not np.array_equal(
        u, hashrand.counter_uniform(7, 0xB1, np.arange(4096), 4))
    g = hashrand.counter_normal(7, 0x57, np.arange(4096))
    assert abs(g.mean()) < 0.06 and abs(g.std() - 1.0) < 0.06
    lo, hi = hashrand.edge_canonical(np.array([3, 5]), np.array([5, 3]))
    assert np.array_equal(lo, [3, 3]) and np.array_equal(hi, [5, 5])


# ---------------------------------------------------------------------------
# 4. Host equivalence on the Figure-2 scenario
# ---------------------------------------------------------------------------

def test_figure2_host_losses_dense_vs_sparse():
    """The §6 random-sun protocol at n=64: the same run through the dense
    host path and through the edge-list plan must trace the same losses."""
    n, d, m = 64, 8, 16
    ws = exp.registry.build_topology(exp.TopologySpec(kind="random-sun"), n)
    H, y = logreg_dataset(n, m, d, seed=0)
    _, _, stoch, _, gnorm2 = logreg_loss_and_grad(rho=0.1)
    grad_fn = lambda xs, key: stoch(xs, H, y, key, 8)
    eval_fn = lambda xb: gnorm2(xb, H, y)
    rule = engine.make_rule("mc_dsgt", gamma=0.3, R=2)
    algo = alg.from_rule(rule, None)
    x0 = jnp.zeros((n, d))

    def run(schedule, impl, plan=None):
        _, hist = driver.run_algorithm(
            algo, x0, grad_fn, schedule, 6, jax.random.key(0),
            eval_fn=eval_fn, eval_every=1, gossip_impl=impl, plan=plan)
        return np.array([float(v) for _, v in hist])

    base = run(ws, "dense")
    sws = sparse.from_weight_schedule(ws)
    got = run(sws, "auto", plan=sws.plan())
    assert base[-1] < base[0]  # the scenario actually optimizes
    assert np.allclose(got, base, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# 5. Telemetry: gap proxy and sender-only wire pricing
# ---------------------------------------------------------------------------

def test_sparse_gap_matches_dense_windowed_gap():
    ws = gossip.schedule_from_topology(
        topo.StaticSchedule(topo.ring_graph(16)))
    mats = np.stack([np.asarray(ws(t), np.float64) for t in range(2)])
    dense_gap = sim_telemetry.windowed_spectral_gap(mats)
    assert 0.0 < dense_gap < 1.0  # a discriminating window
    rounds = [sparse.round_from_dense(M) for M in mats]
    got = sparse.sparse_windowed_gap(rounds, iters=60)
    assert abs(got - dense_gap) < 1e-5
    assert sparse.sparse_windowed_gap(
        [sparse.SparseRound(8, np.empty(0, np.int32),
                            np.empty(0, np.int32), np.empty(0))]) == 0.0


def test_sparse_step_bytes_counts_participating_senders():
    from repro.core import compress

    sws = sparse.sampled_weight_schedule(64, 8, horizon=4, seed=6)
    rec = sparse.SparseTelemetryRecorder(sws, wps=2)

    class St:
        x = jnp.zeros((64, 4))

    entry = rec.record(0, 2, St(), {}, 0.0)
    per = compress.payload_bytes(4, "none")
    want = (sws.round(0).senders + sws.round(1).senders) * per
    assert entry["bytes"] == want
    assert rec.bytes_total == want
    assert entry["spectral_gap"] is not None
    assert entry["eff_diameter"] is None
    assert set(entry["kinds"]) <= {"empty", "matching", "sparse"}


# ---------------------------------------------------------------------------
# 6. exp integration: the random-sampled family end to end
# ---------------------------------------------------------------------------

def _sampled_spec(**over):
    base = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=8, m=16),
        data=exp.DataSpec(batch=4),
        topology=exp.TopologySpec(kind="random-sampled", sample_k=16),
        run=exp.RunSpec(steps=2, nodes=128, gossip_impl="auto"))
    return exp.with_overrides(base, over)


def test_exp_random_sampled_end_to_end():
    spec = _sampled_spec(**{"channel.link_drop": 0.2})
    res = exp.run(spec, quiet=True)
    built = res.built
    assert getattr(built.schedule, "is_sparse", False)
    assert isinstance(res.telemetry, sparse.SparseTelemetryRecorder)
    assert set(built.plan.kinds) <= {"empty", "matching", "sparse"}
    realized = built.realized
    assert realized["edges_per_round"]["max"] <= 16 * 15
    assert realized["senders_per_round"]["max"] <= 16
    assert np.isfinite(float(res.history[-1][1]))


def test_exp_random_sampled_dense_matches_auto():
    la = [float(v) for _, v in
          exp.run(_sampled_spec(), quiet=True).history]
    ld = [float(v) for _, v in
          exp.run(_sampled_spec(**{"run.gossip_impl": "dense"}),
                  quiet=True).history]
    assert np.allclose(la, ld, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("over,match", [
    ({"topology.sample_k": 0}, "sample_k"),
    ({"topology.sample_k": 4096}, "sample_k"),
    ({"model.kind": "arch"}, "logreg"),
    ({"run.nodes": 10_000, "topology.sample_k": 16,
      "run.gossip_impl": "dense"}, "dense guard"),
])
def test_exp_random_sampled_validation(over, match):
    with pytest.raises(ValueError, match=match):
        exp.build(_sampled_spec(**over))


def test_spec_sample_k_roundtrips():
    spec = _sampled_spec()
    assert exp.from_json(exp.to_json(spec)) == spec
    assert "sample_k" in exp.to_json(spec)


# ---------------------------------------------------------------------------
# 7. Scale: staging cost follows edges, not nodes
# ---------------------------------------------------------------------------

def test_plan_restage_scales_with_edges():
    from repro.sparse.smoke import plan_scale_smoke
    out = plan_scale_smoke(n_small=2_000, n_big=40_000, k=64, rounds=4,
                           factor=10.0)
    assert out["edges_big"] < 64 * 63 * 4 + 1  # O(k^2 * rounds), not O(n)
