"""Dirichlet-heterogeneous node data partitions (the federated non-iid
protocol) — token-stream marginals and labelled-pool partitions."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro import configs
from repro.data import (dirichlet_partition, logreg_dataset_dirichlet,
                        token_stream_for)
from repro.data.synthetic import TokenStream


# ---------------------------------------------------------------------------
# dirichlet_partition
# ---------------------------------------------------------------------------

def test_partition_is_exact_and_deterministic():
    labels = np.repeat([0, 1, 2], 60)
    p1 = dirichlet_partition(labels, 8, alpha=0.3, seed=4)
    p2 = dirichlet_partition(labels, 8, alpha=0.3, seed=4)
    allidx = np.concatenate(p1)
    assert sorted(allidx.tolist()) == list(range(len(labels)))  # exact cover
    assert all(len(p) > 0 for p in p1)                          # no empties
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)                     # seeded


def test_small_alpha_concentrates_large_alpha_balances():
    labels = np.repeat([0, 1, 2, 3], 250)
    n = 8

    def mean_top_frac(alpha):
        parts = dirichlet_partition(labels, n, alpha, seed=0)
        fracs = []
        for p in parts:
            counts = np.bincount(labels[p], minlength=4)
            fracs.append(counts.max() / max(counts.sum(), 1))
        return float(np.mean(fracs))

    skewed, balanced = mean_top_frac(0.05), mean_top_frac(100.0)
    assert skewed > 0.75, skewed       # near-single-class nodes
    assert balanced < 0.40, balanced   # ~0.25 at iid
    assert skewed > balanced + 0.25


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 200), n_nodes=st.integers(2, 12),
       alpha=st.floats(0.05, 10.0))
def test_property_partition_always_exact_cover(seed, n_nodes, alpha):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, size=200)
    parts = dirichlet_partition(labels, n_nodes, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 200
    assert len(np.unique(allidx)) == 200
    assert all(len(p) > 0 for p in parts)


# ---------------------------------------------------------------------------
# TokenStream hetero_alpha
# ---------------------------------------------------------------------------

def _stream(alpha, n=4, vocab=32, seed=0):
    return TokenStream(vocab_size=1024, n_nodes=n, rounds=2, batch=2, seq=64,
                       seed=seed, active_vocab=vocab, hetero_alpha=alpha)


def test_hetero_stream_shapes_and_range():
    s = _stream(0.1)
    b = s.batch_at(3)
    assert b["tokens"].shape == (4, 2, 2, 64)
    assert b["tokens"].dtype == jnp.int32
    toks = np.asarray(b["tokens"])
    assert toks.min() >= 0 and toks.max() < 32
    np.testing.assert_array_equal(toks, np.asarray(s.batch_at(3)["tokens"]))


def test_hetero_stream_matches_node_marginals():
    """Each node's empirical token distribution follows ITS Dirichlet draw:
    nodes differ from each other at small alpha, and each node's samples
    are closer to its own marginal than to the other nodes'."""
    s = _stream(0.1, n=4, vocab=16)
    probs = np.exp(np.asarray(s.node_token_logits()))
    counts = np.zeros((4, 16))
    for step in range(8):
        toks = np.asarray(s.batch_at(step)["tokens"])
        for i in range(4):
            counts[i] += np.bincount(toks[i].ravel(), minlength=16)
    emp = counts / counts.sum(axis=1, keepdims=True)
    for i in range(4):
        dists = [np.abs(emp[i] - probs[j]).sum() for j in range(4)]
        assert int(np.argmin(dists)) == i, (i, dists)
    # small alpha => node marginals genuinely differ
    assert max(np.abs(emp[0] - emp[j]).sum() for j in range(1, 4)) > 0.5


def test_iid_stream_unchanged_without_alpha():
    """hetero_alpha=None keeps the original uniform stream bit-for-bit (the
    default path must not shift any seeded trajectory)."""
    cfg = configs.get("qwen1.5-0.5b").reduced()
    a = token_stream_for(cfg, 4, 2, 2, 32, seed=0, active_vocab=16)
    b = token_stream_for(cfg, 4, 2, 2, 32, seed=0, active_vocab=16,
                         hetero_alpha=None)
    np.testing.assert_array_equal(np.asarray(a.batch_at(5)["tokens"]),
                                  np.asarray(b.batch_at(5)["tokens"]))


# ---------------------------------------------------------------------------
# logreg_dataset_dirichlet
# ---------------------------------------------------------------------------

def test_logreg_dirichlet_shapes_and_skew():
    n, m, d = 8, 64, 16
    H, y = logreg_dataset_dirichlet(n, m, d, alpha=0.05, seed=0)
    assert H.shape == (n, m, d) and y.shape == (n, m)
    assert set(np.unique(np.asarray(y))) <= {-1.0, 1.0}
    # label skew per node: small alpha pushes nodes toward one class
    pos_frac = np.asarray((y > 0).mean(axis=1))
    assert np.mean(np.maximum(pos_frac, 1 - pos_frac)) > 0.8
    Hb, yb = logreg_dataset_dirichlet(n, m, d, alpha=100.0, seed=0)
    pos_b = np.asarray((yb > 0).mean(axis=1))
    assert np.mean(np.maximum(pos_b, 1 - pos_b)) < 0.65
