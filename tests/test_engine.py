"""Single-source engine tests.

1. Engine-vs-legacy parity: the engine-built steps must reproduce the
   pre-refactor update arithmetic NUMERICALLY — the host formulas for all
   four paper algorithms, and the distributed cores on BOTH gossip paths
   (dense einsum and the planned auto dispatcher).  The legacy updates are
   spelled out inline here (the tests are the oracle; the runtimes no
   longer contain them).
2. Properties of the new federated rules: local_sgd reduces to parallel
   per-node SGD on empty rounds and to centralized SGD on the complete
   graph; gt_local's tracker keeps the mean-tracking invariant and removes
   the heterogeneity bias local_sgd suffers on a federated schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import algorithms as alg, engine, gossip, topology as topo
from repro.dist import steps as dsteps


def _quadratic(n=8, d=5, hetero=2.0, seed=0):
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)) * hetero)

    def grad_fn(xs, key):
        return xs - centers

    return centers, grad_fn


def _tree_err(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# 1a. Host parity: engine rules == the pre-refactor update formulas
# ---------------------------------------------------------------------------

def _legacy_host_step(name, x, h, g_prev, Ws, grad_fn, key, gamma, R):
    """The pre-refactor update arithmetic, verbatim (deterministic grads, so
    the old DSGT key-split quirk is irrelevant)."""
    mc = alg.multi_consensus
    if name == "dsgd":
        g = grad_fn(x, key)
        return mc(Ws, jax.tree.map(lambda a, b: a - gamma * b, x, g)), h, g_prev
    if name == "dsgt":
        x = alg.mix(Ws[0], jax.tree.map(lambda a, b: a - gamma * b, x, h))
        g = grad_fn(x, key)
        h = alg.mix(Ws[1], jax.tree.map(lambda hh, gi, gp: hh + gi - gp,
                                        h, g, g_prev))
        return x, h, g
    if name == "mc_dsgt":
        x = mc(Ws[:R], jax.tree.map(lambda a, b: a - gamma * b, x, h))
        g = alg._accumulate(grad_fn, x, key, R)
        h = mc(Ws[R:], jax.tree.map(lambda hh, gi, gp: hh + gi - gp,
                                    h, g, g_prev))
        return x, h, g
    if name == "d2":  # h slot plays x^{k-1}
        g = grad_fn(x, key)
        z = jax.tree.map(lambda xk, xm, gk, gm: 2 * xk - xm - gamma * (gk - gm),
                         x, h, g, g_prev)
        return alg.mix(Ws[0], z), x, g
    raise ValueError(name)


@pytest.mark.parametrize("name,R", [("dsgd", 1), ("dsgt", 1),
                                    ("mc_dsgt", 2), ("d2", 1)])
def test_host_engine_matches_legacy_formulas(name, R):
    n, d, gamma, steps = 8, 5, 0.3, 4
    centers, grad_fn = _quadratic(n, d)
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    x0 = jnp.zeros((n, d))
    factory = {"dsgd": lambda: alg.dsgd(gamma), "dsgt": lambda: alg.dsgt(gamma),
               "mc_dsgt": lambda: alg.mc_dsgt(gamma, R=R),
               "d2": lambda: alg.d2(gamma)}[name]
    algo = factory()
    state = alg.warm_start(algo, algo.init(x0), grad_fn, jax.random.key(0))

    # legacy trajectory from the same warm state (for d2, h plays x^{-1})
    x, h, g_prev = state.x, state.h, state.g_prev
    t = 0
    for k in range(steps):
        Ws = jnp.asarray(sched.stacked(t, algo.weights_per_step))
        key = jax.random.key(k + 1)
        state = algo.step(state, grad_fn, Ws, key)
        x, h, g_prev = _legacy_host_step(name, x, h, g_prev, Ws, grad_fn,
                                         key, gamma, R)
        t += algo.weights_per_step
        assert _tree_err(state.x, x) < 1e-6, (name, k)
        if h is not None and state.h is not None:
            assert _tree_err(state.h, h) < 1e-6, (name, k)


# ---------------------------------------------------------------------------
# 1b. Dist parity: engine-built steps == the pre-refactor cores,
#     dense AND auto gossip paths (toy model => millisecond compiles)
# ---------------------------------------------------------------------------

class ToyModel:
    """Linear regression with the model interface make_train_step needs."""

    d = 6

    def init(self, key, dtype):
        k1, k2 = jax.random.split(key)
        return {"w": 0.1 * jax.random.normal(k1, (self.d,), dtype),
                "b": 0.1 * jax.random.normal(k2, (), dtype)}

    def train_loss(self, params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)


def _toy_batch(n, R, bsz, d, seed):
    k = jax.random.key(seed)
    x = jax.random.normal(k, (n, R, bsz, d))
    y = jax.random.normal(jax.random.fold_in(k, 1), (n, R, bsz))
    return {"x": x, "y": y}


def _legacy_grads(model, x_stacked, batch, R, clip=1.0):
    """Verbatim pre-refactor _grads: per-node R-microbatch accumulation,
    then the global-norm clip."""
    def clipf(g):
        nrm = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                           for l in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, clip / (nrm + 1e-12))
        return jax.tree.map(lambda l: l * scale.astype(l.dtype), g)

    def per_node(params, node_batch):
        vg = jax.value_and_grad(model.train_loss)
        loss = jnp.zeros((), jnp.float32)
        g = jax.tree.map(jnp.zeros_like, params)
        for r in range(R):
            l, gr = vg(params, jax.tree.map(lambda t: t[r], node_batch))
            loss = loss + l
            g = jax.tree.map(jnp.add, g, gr)
        return loss / R, clipf(jax.tree.map(lambda t: t / R, g))

    losses, grads = jax.vmap(per_node)(x_stacked, batch)
    return jnp.mean(losses), grads


def _legacy_dist_step(model, algo, state, batch, Ws, gamma, R):
    """The pre-refactor dsgd_core / tracker_core / d2_core, verbatim."""
    mc = alg.multi_consensus
    if algo == "dsgd":
        loss, g = _legacy_grads(model, state.x, batch, R)
        x = mc(Ws[:R], jax.tree.map(lambda a, b: a - gamma * b, state.x, g))
        return state._replace(x=x, step=state.step + 1), loss
    if algo in ("dsgt", "mc_dsgt"):
        x = mc(Ws[:R], jax.tree.map(lambda a, b: a - gamma * b,
                                    state.x, state.h))
        loss, g = _legacy_grads(model, x, batch, R)
        delta = jax.tree.map(lambda h, gi, gp: h + gi - gp,
                             state.h, g, state.g_prev)
        h = mc(Ws[R:], delta)
        return state._replace(x=x, h=h, g_prev=g, step=state.step + 1), loss
    # d2
    loss, g = _legacy_grads(model, state.x, batch, R)
    z = jax.tree.map(lambda xk, xm, gk, gp: 2.0 * xk - xm - gamma * (gk - gp),
                     state.x, state.h, g, state.g_prev)
    x = mc(Ws[:1], z)
    return state._replace(x=x, h=state.x, g_prev=g, step=state.step + 1), loss


@pytest.mark.parametrize("algo,R", [("dsgd", 1), ("dsgt", 1),
                                    ("mc_dsgt", 2), ("d2", 1)])
def test_dist_engine_matches_legacy_cores_both_gossip_paths(algo, R):
    model = ToyModel()
    n, gamma = 8, 0.1
    wps = engine.make_rule(algo, gamma=gamma, R=R).weights_per_step
    sched = gossip.theorem3_weight_schedule(n, 0.6)
    plan = sched.plan()
    batch0 = _toy_batch(n, R, 3, model.d, seed=0)
    batch1 = _toy_batch(n, R, 3, model.d, seed=1)

    init_d, warm_d, step_d = dsteps.make_train_step(
        model, None, algo=algo, gamma=gamma, R=R)
    init_a, warm_a, step_a = dsteps.make_train_step(
        model, None, algo=algo, gamma=gamma, R=R, gossip_impl="auto",
        plan=plan)

    state0 = warm_d(init_d(jax.random.key(0), n, jnp.float32), batch0)
    Ws = jnp.asarray(sched.stacked(0, max(wps, 1)))

    # legacy reference from the identical warm state
    ref, ref_loss = _legacy_dist_step(model, algo, state0, batch1, Ws,
                                      gamma, R)
    # engine, dense path
    got_d, m_d = jax.jit(step_d)(state0, batch1, Ws)
    # engine, auto (planned) path at the same start round
    state0a = warm_a(init_a(jax.random.key(0), n, jnp.float32), batch0)
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    if step_a.gossip_dispatch == "static":
        got_a, m_a = jax.jit(step_a, static_argnums=3)(state0a, batch1,
                                                       tensors, 0)
    else:
        got_a, m_a = jax.jit(step_a)(state0a, batch1, tensors, 0)

    for got, m in ((got_d, m_d), (got_a, m_a)):
        np.testing.assert_allclose(float(m["loss"]), float(ref_loss),
                                   rtol=1e-6)
        assert _tree_err(got.x, ref.x) < 1e-5
        assert _tree_err(got.h, ref.h) < 1e-5
        assert _tree_err(got.g_prev, ref.g_prev) < 1e-5


@pytest.mark.parametrize("algo", ["local_sgd", "gt_local"])
def test_dist_new_rules_dense_equals_auto(algo):
    """The federated rules run in the dist runtime and the two gossip
    paths agree — on the federated plan itself (empty + complete rounds)."""
    model = ToyModel()
    n, gamma = 8, 0.1
    sched = gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    plan = sched.plan()
    batch0 = _toy_batch(n, 1, 3, model.d, seed=0)
    init_d, warm_d, step_d = dsteps.make_train_step(
        model, None, algo=algo, gamma=gamma, R=1)
    init_a, warm_a, step_a = dsteps.make_train_step(
        model, None, algo=algo, gamma=gamma, R=1, gossip_impl="auto",
        plan=plan)
    sd = warm_d(init_d(jax.random.key(0), n, jnp.float32), batch0)
    sa = warm_a(init_a(jax.random.key(0), n, jnp.float32), batch0)
    ja = (jax.jit(step_a, static_argnums=3)
          if step_a.gossip_dispatch == "static" else jax.jit(step_a))
    jd = jax.jit(step_d)
    tensors = jax.tree.map(jnp.asarray, plan.tensors())
    for t in range(plan.period):  # one full period: local rounds + the avg
        batch = _toy_batch(n, 1, 3, model.d, seed=t + 1)
        W = jnp.asarray(sched.stacked(t, 1))
        sd, md = jd(sd, batch, W)
        sa, ma = ja(sa, batch, tensors, t)
        np.testing.assert_allclose(float(md["loss"]), float(ma["loss"]),
                                   rtol=1e-6)
        assert _tree_err(sd.x, sa.x) < 1e-5


# ---------------------------------------------------------------------------
# 2. Properties of the federated rules
# ---------------------------------------------------------------------------

def test_rule_budget_accounting():
    """weights_per_step: the paper's gossip/oracle budget per step."""
    mk = lambda name, R=1: engine.make_rule(name, gamma=0.1, R=R)
    assert mk("dsgd").weights_per_step == 1
    assert mk("dsgd", R=3).weights_per_step == 3
    assert mk("dsgt").weights_per_step == 2
    assert mk("mc_dsgt", R=4).weights_per_step == 8
    assert mk("local_sgd").weights_per_step == 1
    assert mk("gt_local").weights_per_step == 1  # x and h share the round
    assert mk("d2").weights_per_step == 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), n_pow=st.integers(1, 3))
def test_local_sgd_empty_rounds_are_pure_local_steps(seed, n_pow):
    """On the empty graph (W = I), a local_sgd step is exactly one
    independent SGD step per node."""
    n, d, gamma = 2 ** n_pow, 4, 0.2
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)))
    x0 = jnp.asarray(rng.normal(size=(n, d)))

    def grad_fn(xs, key):
        return xs - centers

    algo = alg.local_sgd(gamma)
    W = jnp.eye(n)[None]
    state = algo.step(algo.init(x0), grad_fn, W, jax.random.key(0))
    want = x0 - gamma * (x0 - centers)  # per-node SGD, no mixing
    np.testing.assert_allclose(np.asarray(state.x), np.asarray(want),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_local_sgd_complete_graph_is_parallel_sgd(seed):
    """On the complete graph (W = 11^T/n) local_sgd IS centralized SGD:
    every node mixes to the mean first, so all copies follow one
    trajectory."""
    n, d, gamma, steps = 8, 3, 0.3, 5
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)))
    x0 = jnp.asarray(rng.normal(size=(n, d)))

    def grad_fn(xs, key):
        return xs - centers

    algo = alg.local_sgd(gamma)
    W = jnp.ones((1, n, n)) / n
    state = algo.init(x0)
    xc = jnp.mean(x0, axis=0)  # centralized reference
    for k in range(steps):
        state = algo.step(state, grad_fn, W, jax.random.key(k))
        xc = xc - gamma * (xc - jnp.mean(centers, axis=0))
        for i in range(n):
            np.testing.assert_allclose(np.asarray(state.x[i]),
                                       np.asarray(xc), atol=1e-5)


def test_gt_local_tracker_mean_invariant():
    """Gradient tracking invariant: mean_i h_i^k == mean_i g_i^k after every
    step — including through the empty (local-only) federated rounds, which
    is exactly what correction-outside-the-mix buys."""
    n, d = 8, 4
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 3.0)

    def grad_fn(xs, key):
        return xs - centers

    sched = gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    algo = alg.gt_local(0.2)
    state = alg.warm_start(algo, algo.init(jnp.zeros((n, d))), grad_fn,
                           jax.random.key(0))
    t = 0
    for k in range(12):
        Ws = jnp.asarray(sched.stacked(t, 1))
        state = algo.step(state, grad_fn, Ws, jax.random.key(k))
        t += 1
        np.testing.assert_allclose(np.asarray(state.h.mean(0)),
                                   np.asarray(state.g_prev.mean(0)),
                                   atol=1e-6)


def test_gt_local_removes_federated_heterogeneity_bias():
    """On a federated schedule with heterogeneous curvature, local_sgd (like
    DSGD) stalls at a biased point while gt_local converges exactly — the
    tracking analogue of the DSGD-vs-DSGT separation, now for the
    local-update family."""
    n, d = 16, 4
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 5.0)
    hess = jnp.asarray(rng.uniform(0.2, 1.8, size=(n, d)))

    def grad_fn(xs, key):
        return hess * (xs - centers)

    xstar = (hess * centers).mean(0) / hess.mean(0)
    sched = gossip.schedule_from_topology(topo.federated_schedule(n, 4))
    x0 = jnp.zeros((n, d))
    s_lsgd, _ = alg.run(alg.local_sgd(0.3), x0, grad_fn, sched, 800,
                        jax.random.key(0))
    s_gt, _ = alg.run(alg.gt_local(0.3), x0, grad_fn, sched, 800,
                      jax.random.key(0))
    err_lsgd = float(jnp.linalg.norm(s_lsgd.x.mean(0) - xstar))
    err_gt = float(jnp.linalg.norm(s_gt.x.mean(0) - xstar))
    assert err_gt < 1e-3, err_gt
    assert err_lsgd > 10 * max(err_gt, 1e-6), (err_lsgd, err_gt)


def test_d2_rejects_local_opt():
    from repro.optim import momentum
    with pytest.raises(ValueError):
        alg.from_rule(engine.make_rule("d2", 0.1), momentum())
    with pytest.raises(ValueError):
        dsteps.make_train_step(ToyModel(), None, algo="d2", gamma=0.1,
                               local_opt=momentum())


# ---------------------------------------------------------------------------
# 3. CLI integration: --local-opt and the federated scenario
# ---------------------------------------------------------------------------

def test_cli_local_opt_smoke():
    """--local-opt runs on both the dense and auto gossip paths."""
    from repro.launch.train import main as train_main
    base = ["--arch", "qwen1.5-0.5b", "--preset", "reduced", "--steps", "2",
            "--nodes", "4", "--batch", "1", "--seq", "16"]
    h1 = train_main(base + ["--local-opt", "momentum",
                            "--gossip-impl", "dense"])
    h2 = train_main(base + ["--local-opt", "adam", "--gossip-impl", "auto",
                            "--topology", "federated", "--algo", "local_sgd"])
    assert len(h1) == len(h2) == 2
    assert all(np.isfinite(h["loss"]) for h in h1 + h2)


def test_cli_local_sgd_federated_hetero_decreases_loss():
    """The ISSUE acceptance scenario (miniaturized): local_sgd over the
    federated topology with Dirichlet(0.1) heterogeneity, auto gossip."""
    from repro.launch.train import main as train_main
    hist = train_main(["--arch", "qwen1.5-0.5b", "--preset", "reduced",
                       "--steps", "10", "--nodes", "4", "--batch", "1",
                       "--seq", "16", "--algo", "local_sgd",
                       "--topology", "federated", "--hetero-alpha", "0.1",
                       "--gossip-impl", "auto"])
    assert len(hist) == 10
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3, \
        (hist[0]["loss"], hist[-1]["loss"])
