"""repro.obs: in-jit metric parity across runtimes, flush completeness,
sinks, tracing, the optimality gap, report rendering, and the telemetry
round cache."""

import io
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg, driver, engine, gossip
from repro.obs import (
    Console,
    EventLog,
    GapTracker,
    MemorySink,
    ObsRecorder,
    Profiler,
    Tracer,
    cell_key,
    read_events,
    theoretical_floor,
)
from repro.obs import metrics as obs_metrics, optimality, report
from repro.sim.telemetry import TelemetryRecorder

N, D = 4, 6
KEY = jax.random.key(0)
TARGETS = jnp.asarray(np.random.default_rng(7).normal(size=(N, D)),
                      jnp.float32)


class _QuadModel:
    """Dist-runtime model with the same oracle as the host quadratic:
    loss 0.5 ||w - target||^2 per node, so grad = w - target."""

    def init(self, key, dtype):
        del key
        return {"w": jnp.zeros((D,), dtype)}

    def train_loss(self, params, batch):
        return 0.5 * jnp.sum((params["w"] - batch["t"][0]) ** 2)


def _host_grad(xs, key):
    del key
    return xs - TARGETS


def _dist_batch(R):
    # (n, R, b=1, d): every microbatch repeats the node's target, so the
    # R-sample mean equals the host's deterministic oracle
    t = jnp.broadcast_to(TARGETS[:, None, None, :], (N, R, 1, D))
    return {"t": t}


def _sched():
    return gossip.theorem3_weight_schedule(N, 0.75)


def _series(algo_name, R, impl, runtime, steps=3):
    """Per-step obs dicts for one (algorithm, gossip impl, runtime)."""
    from repro.dist import steps as dsteps

    sched = _sched()
    rule = engine.make_rule(algo_name, gamma=0.1, R=R)
    names = engine.default_obs(rule)
    wps = rule.weights_per_step
    plan = sched.plan(0, sched.period)
    tensors = driver.stage_plan(plan)
    out = []
    if runtime == "host":
        algo = alg.from_rule(rule)
        state = algo.init(jnp.zeros((N, D)))
        state = algo.warm(state, _host_grad, KEY)
        pstep = alg.plan_step(algo, plan)
        for k in range(steps):
            t = k * wps % sched.period
            if impl == "dense":
                Ws = jnp.asarray(sched.stacked(t, wps))
                state, scal = algo.step(state, _host_grad, Ws, KEY,
                                        obs=names)
            else:
                state, scal = pstep(state, _host_grad, tensors, t, KEY,
                                    obs=names)
            out.append(jax.device_get(scal))
    else:
        init_state, warm_start, train_step = dsteps.make_train_step(
            _QuadModel(), None, algo=algo_name, gamma=0.1, R=R,
            clip=None, gossip_impl=impl, plan=(plan if impl == "auto"
                                               else None), obs=names)
        batch = _dist_batch(R)
        state = init_state(KEY, N, jnp.float32)
        state = warm_start(state, batch)
        for k in range(steps):
            t = k * wps % sched.period
            if impl == "dense":
                Ws = jnp.asarray(sched.stacked(t, wps))
                state, o = train_step(state, batch, Ws)
            else:
                state, o = train_step(state, batch, tensors, t)
            out.append(jax.device_get(o["obs"]))
    return out


@pytest.mark.parametrize("impl", ["dense", "auto"])
@pytest.mark.parametrize("algo_name,R", [("dsgd", 1), ("mc_dsgt", 2)])
def test_metric_parity_host_vs_dist(algo_name, R, impl):
    """Both runtimes bind the SAME engine metrics: identical oracle +
    schedule must emit matching grad-norm/consensus/... series."""
    host = _series(algo_name, R, impl, "host")
    dist = _series(algo_name, R, impl, "dist")
    assert len(host) == len(dist) == 3
    for k, (h, d) in enumerate(zip(host, dist)):
        assert set(h) == set(d)
        for name in h:
            np.testing.assert_allclose(
                float(h[name]), float(d[name]), rtol=1e-5, atol=1e-6,
                err_msg=f"{algo_name}/{impl} step {k} metric {name}")
    # the series must be non-trivial: gradients exist, and without exact
    # averaging (dsgd's single round) nodes disagree
    assert float(host[0]["grad_norm"]) > 0.1
    if algo_name == "dsgd":
        assert float(host[-1]["consensus"]) > 0


@pytest.mark.parametrize("algo_name,has_tracker",
                         [("dsgd", False), ("local_sgd", False),
                          ("dsgt", True), ("mc_dsgt", True),
                          ("gt_local", True), ("d2", False)])
def test_default_obs_per_rule(algo_name, has_tracker):
    rule = engine.make_rule(algo_name, gamma=0.1,
                            R=(2 if algo_name == "mc_dsgt" else 1))
    names = engine.default_obs(rule)
    assert ("tracker_residual" in names) == has_tracker
    assert "grad_norm" in names and "consensus" in names


def test_tracking_invariant_small_residual():
    """mean(h) = mean(g) under doubly-stochastic mixing: with no clipping
    and f32 trackers the measured residual is numerical noise."""
    series = _series("mc_dsgt", 2, "dense", "dist", steps=4)
    for s in series:
        assert float(s["tracker_residual"]) < 1e-4


def test_every_flush_loses_no_events():
    """every > 1 batches host transfers but every recorded step must land
    in the sink (tail flushed by close)."""
    sink = MemorySink()
    rec = ObsRecorder(sink, every=4)
    for k in range(10):  # 10 % 4 != 0: the tail only flushes on close
        rec.record(k, (k + 1) * 2, None,
                   {"loss": jnp.float32(k), "obs": {"grad_norm":
                                                    jnp.float32(1.0 + k)}},
                   0.01)
    rec.close()
    steps = [e for e in sink.events if e["event"] == "step"]
    assert [e["step"] for e in steps] == list(range(10))
    assert [e["grad_norm"] for e in steps] == [1.0 + k for k in range(10)]
    assert sink.events[-1]["event"] == "summary"
    assert sink.closed


def test_event_log_jsonl(tmp_path):
    path = str(tmp_path / "sub" / "log.jsonl")  # parent dir auto-created
    log = EventLog(path)
    rec = ObsRecorder(log, every=2, meta={"name": "t", "n": N})
    rec.record(0, 2, None, {"obs": {"grad_norm": jnp.float32(3.0)}}, 0.5)
    rec.eval_event(0, 2, 0.25)
    rec.close()
    events = read_events(path)
    assert [e["event"] for e in events] == ["meta", "step", "eval",
                                            "summary"]
    assert events[0]["n"] == N
    assert events[1]["grad_norm"] == 3.0
    assert read_events(path, "eval") == [{"event": "eval", "step": 0,
                                          "t": 2, "value": 0.25}]


def test_telemetry_chained_not_replaced():
    """An existing TelemetryRecorder rides along: its windowed fields land
    on the step events AND its own history keeps filling."""
    sched = _sched()
    telem = TelemetryRecorder(sched, wps=2, window=4)
    sink = MemorySink()
    rec = ObsRecorder(sink, every=1, telemetry=telem)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(N, D)),
                    jnp.float32)

    class _S:
        pass

    s = _S()
    s.x = x
    for k in range(3):
        rec.record(k, (k + 1) * 2, s,
                   {"obs": {"consensus": jnp.float32(1.0)}}, 0.01)
    rec.close()
    steps = [e for e in sink.events if e["event"] == "step"]
    assert len(telem.history) == 3 == len(steps)
    assert all("spectral_gap" in e and "kinds" in e for e in steps)
    # the in-jit consensus wins over the recorder's host-side copy
    assert all(e["consensus"] == 1.0 for e in steps)


def test_telemetry_cache_matches_uncached():
    sched = _sched()
    cached = TelemetryRecorder(sched, wps=2, window=6, cache=True)
    plain = TelemetryRecorder(sched, wps=2, window=6, cache=False)

    class _S:
        x = jnp.ones((N, D))

    for k in range(8):
        a = cached.record(k, (k + 1) * 2, _S(), None, 0.0)
        b = plain.record(k, (k + 1) * 2, _S(), None, 0.0)
        assert a == b
    # eviction: only rounds inside the current window stay cached
    assert all(r >= 16 - 6 for r in cached._rounds)


def test_resolve_names():
    assert obs_metrics.resolve_names(None) == ()
    assert obs_metrics.resolve_names("") == ()
    assert obs_metrics.resolve_names("grad_norm, consensus") == \
        ("grad_norm", "consensus")
    assert obs_metrics.resolve_names("auto") == engine.OBS_METRICS
    rule = engine.make_rule("dsgd", gamma=0.1)
    assert "tracker_residual" not in obs_metrics.resolve_names("auto", rule)
    with pytest.raises(ValueError, match="unknown obs metric"):
        obs_metrics.resolve_names("grad_norm,bogus")


def test_tracer_spans_and_drain():
    tr = Tracer()
    with tr.span("step"):
        pass
    with tr.span("step"):
        pass
    with tr.span("data"):
        pass
    pending = tr.drain()
    assert set(pending) == {"step", "data"}
    assert tr.drain() == {}  # drained
    s = tr.summary()
    assert s["step"]["count"] == 2 and s["data"]["count"] == 1
    assert s["step"]["total_sec"] >= 0


def test_profiler_writes_trace(tmp_path):
    prof = Profiler(str(tmp_path / "trace"), steps=2)
    prof.start()
    assert not prof.maybe_stop(0)
    assert prof.maybe_stop(1)  # stops at the Nth recorded step
    prof.close()  # idempotent
    assert os.path.isdir(str(tmp_path / "trace"))


def test_theoretical_floor_regimes():
    # statistical term ~ 1/sqrt(nT): quadrupling T halves it
    f1 = theoretical_floor(1000, n=8, beta=0.0, sigma=1.0)
    f4 = theoretical_floor(4000, n=8, beta=0.0, sigma=1.0)
    net1 = 1.0 / 1000  # beta=0 network term = Delta L / T
    net4 = 1.0 / 4000
    assert (f1 - net1) / (f4 - net4) == pytest.approx(2.0, rel=1e-6)
    # network term scales as 1/(1-beta): beta .99 vs .5 is exactly 50x
    assert theoretical_floor(1000, n=8, beta=0.99, sigma=0.0) == \
        pytest.approx(50 * theoretical_floor(1000, n=8, beta=0.5,
                                             sigma=0.0))
    # full-batch: sigma=0 leaves only the network term
    assert theoretical_floor(100, n=4, beta=0.5, sigma=0.0) == \
        pytest.approx(1.0 / (0.5 * 100))


def test_gap_tracker_summary_and_rate():
    g = GapTracker(cell=cell_key("mc_dsgt", "sun", "ideal"), n=8, beta=0.5)
    for t in range(1, 200):
        g.update(t * 4, 10.0 / (t * 4))  # ~ T^{-1} decay
    s = g.summary()
    assert s["cell"] == "mc_dsgt/sun/ideal"
    assert s["T"] == 199 * 4
    assert s["best_grad_sq"] == pytest.approx(10.0 / (199 * 4))
    assert s["floor"] == pytest.approx(
        theoretical_floor(199 * 4, n=8, beta=0.5))
    assert s["gap_ratio"] == pytest.approx(s["best_grad_sq"] / s["floor"])
    assert s["rate_slope"] == pytest.approx(-1.0, abs=0.05)
    # non-finite samples are ignored, not stored
    g.update(1000, float("nan"))
    assert g.summary()["T"] == 199 * 4


def test_gap_tracker_unknown_bound():
    with pytest.raises(ValueError, match="unknown bound"):
        GapTracker(cell="c", n=4, beta=0.5, bound="bogus")


def test_report_renders(tmp_path):
    sink = MemorySink()
    gap = GapTracker(cell="dsgd/ring/ideal", n=4, beta=0.5)
    tr = Tracer()
    rec = ObsRecorder(sink, every=3, tracer=tr, gap=gap,
                      meta={"name": "demo", "algo": "dsgd"})
    for k in range(7):
        with tr.span("step"):
            pass
        rec.record(k, (k + 1) * 2, None,
                   {"loss": jnp.float32(1.0 / (k + 1)),
                    "obs": {"grad_norm": jnp.float32(2.0 / (k + 1))}}, 0.01)
    rec.eval_event(6, 14, 0.5)
    rec.close()
    text = report.render(sink.events)
    assert "demo" in text
    assert "grad_norm" in text and "loss" in text
    assert "optimality gap" in text and "gap ratio" in text
    assert "phases" in text
    assert any(c in text for c in "▁▂▃▄▅▆▇█")
    # the CLI path end to end on a real file
    path = str(tmp_path / "log.jsonl")
    log = EventLog(path)
    for e in sink.events:
        log.emit(e)
    log.close()
    assert report.main([path]) == 0


def test_sparkline():
    assert report.sparkline([]) == ""
    assert report.sparkline([1.0, 1.0]) == "▁▁"
    line = report.sparkline(list(range(64)), width=8)
    assert len(line) == 8 and line[0] == "▁" and line[-1] == "█"


def test_console_quiet_and_events():
    buf = io.StringIO()
    con = Console(quiet=False, stream=buf)
    con.print("hello")
    con.event("result", algo="dsgd", grad_sq=0.125)
    out = buf.getvalue()
    assert "hello" in out
    assert "result algo=dsgd grad_sq=0.125" in out
    qbuf = io.StringIO()
    quiet = Console(quiet=True, stream=qbuf, sink=(sink := MemorySink()))
    quiet.print("nope")
    quiet.event("result", x=1)
    assert qbuf.getvalue() == ""  # silent ...
    assert sink.events == [{"event": "result", "x": 1}]  # ... but logged
    assert Console.from_argv(["--quiet"]).quiet
    assert not Console.from_argv([]).quiet


def test_obsspec_roundtrip_and_validation(tmp_path):
    from repro import exp

    # defaults elide: an obs-less spec serializes exactly as before
    assert exp.to_dict(exp.ExperimentSpec()) == {}
    sp = exp.from_dict({"obs": {"metrics": "x.jsonl", "every": 5}})
    assert sp.obs.metrics == "x.jsonl" and sp.obs.every == 5
    assert sp.obs.enabled
    assert not exp.ExperimentSpec().obs.enabled
    assert exp.from_dict(exp.to_dict(sp)) == sp
    with pytest.raises(KeyError):
        exp.from_dict({"obs": {"bogus": 1}})
    with pytest.raises(ValueError, match="obs.sink"):
        exp.build(exp.from_dict({"obs": {"metrics": "x", "sink": "bogus"}}))
    with pytest.raises(ValueError, match="unknown obs metric"):
        exp.build(exp.from_dict({"obs": {"metrics": "x",
                                         "names": "bogus"}}))
    # obs is observation-only: restore-mismatch diffs ignore it
    assert exp.diff_specs(sp, exp.ExperimentSpec()) == []


def test_exp_run_obs_end_to_end(tmp_path):
    from repro import exp

    log = str(tmp_path / "run.jsonl")
    sp = exp.from_dict({
        "model": {"kind": "logreg", "d": 8, "m": 32},
        "algorithm": {"name": "mc_dsgt", "R": 2},
        "run": {"steps": 5, "nodes": 4, "eval_every": 2},
        "obs": {"metrics": log, "every": 3},
    })
    res = exp.run(sp)
    assert len(res.history) >= 2
    events = read_events(log)
    kinds = [e["event"] for e in events]
    assert kinds[0] == "meta" and kinds[-1] == "summary"
    assert kinds.count("step") == 5
    assert kinds.count("eval") >= 2
    meta = events[0]
    assert meta["cell"] == "mc_dsgt/sun/ideal"
    assert meta["spec_hash"] == exp.spec_hash(sp)
    stepev = next(e for e in events if e["event"] == "step")
    for name in ("grad_norm", "consensus", "mix_residual",
                 "tracker_residual", "sec", "phases"):
        assert name in stepev, name
    summ = events[-1]
    assert summ["optimality"]["gap_ratio"] is not None
    assert {"data", "step", "telemetry"} <= set(summ["phases"])
    # manifest written next to the event log, records the log + obs names
    m = exp.load_manifest(exp.manifest_path(log))
    assert m["spec_parsed"] == sp
    assert m["realized"]["event_log"] == log
    assert "grad_norm" in m["realized"]["obs_names"]


def test_train_cli_metrics_flags(tmp_path):
    from repro.launch import train

    log = str(tmp_path / "cli.jsonl")
    hist = train.main([
        "--steps", "3", "--nodes", "4", "--batch", "1", "--seq", "16",
        "--metrics", log, "--metrics-every", "2", "--quiet"])
    assert len(hist) == 3
    events = read_events(log)
    assert [e["event"] for e in events].count("step") == 3
    assert all(np.isfinite(e["loss"]) for e in events
               if e["event"] == "step")
    # --dump-config round-trips the obs section
    spec = train.main(["--metrics", "m.jsonl", "--dump-config"])
    assert spec.obs.metrics == "m.jsonl"


def test_engine_obs_unknown_name_raises():
    rule = engine.make_rule("dsgd", gamma=0.1)
    algo = alg.from_rule(rule)
    state = algo.init(jnp.zeros((N, D)))
    Ws = jnp.asarray(_sched().stacked(0, 1))
    with pytest.raises(ValueError, match="unknown obs metric"):
        algo.step(state, _host_grad, Ws, KEY, obs=("bogus",))
