"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gossip_matmul import gossip_mix
from repro.kernels.linear_recurrence import linear_recurrence


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Sk, H, KV, hd, causal, window, bq, bk)
    (1, 128, 128, 4, 4, 64, True, 0, 64, 64),
    (2, 256, 256, 4, 2, 64, True, 0, 128, 128),
    (1, 128, 128, 8, 1, 32, True, 0, 64, 64),      # MQA
    (1, 256, 256, 4, 4, 64, True, 64, 64, 64),     # sliding window
    (2, 128, 128, 2, 2, 128, False, 0, 64, 64),    # bidirectional
    (1, 512, 512, 2, 1, 64, True, 128, 128, 128),  # window > block
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, H, KV, hd, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Sk, KV, hd), dtype)
    v = _rand(ks[2], (B, Sk, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_blocks_irrelevant():
    """Output must not depend on the block decomposition."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], (1, 256, 2, 64), jnp.float32)
    k = _rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 256, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# linear recurrence
# ---------------------------------------------------------------------------

LINREC_CASES = [
    # (B, S, C, bt, bc)
    (1, 128, 64, 32, 64),
    (2, 256, 512, 128, 256),
    (1, 64, 1024, 64, 512),
    (3, 128, 32, 128, 32),
]


@pytest.mark.parametrize("case", LINREC_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_recurrence_matches_ref(case, dtype):
    B, S, C, bt, bc = case
    ks = jax.random.split(jax.random.key(2), 2)
    # decay-like a in (0, 1): matches the mamba/rglru regime, keeps the
    # recurrence stable over long horizons
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, C), jnp.float32)).astype(dtype)
    b = _rand(ks[1], (B, S, C), dtype)
    h_all, h_last = linear_recurrence(a, b, block_t=bt, block_c=bc,
                                      interpret=True)
    want_all, want_last = ref.linear_recurrence_ref(a, b)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(want_all),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want_last),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(s_chunks=st.integers(1, 4), c_chunks=st.integers(1, 3),
       seed=st.integers(0, 50))
def test_property_linrec_chunking_invariance(s_chunks, c_chunks, seed):
    """Property: kernel output is independent of the chosen tiling."""
    B, S, C = 1, 32 * s_chunks, 16 * c_chunks
    ks = jax.random.split(jax.random.key(seed), 2)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, C), jnp.float32))
    b = _rand(ks[1], (B, S, C), jnp.float32)
    out1, last1 = linear_recurrence(a, b, block_t=32, block_c=16, interpret=True)
    want_all, want_last = ref.linear_recurrence_ref(a, b)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(want_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(last1), np.asarray(want_last), atol=1e-5)


# ---------------------------------------------------------------------------
# gossip matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,R,D,bd", [(8, 1, 256, 128), (16, 4, 1024, 512),
                                      (32, 8, 512, 512), (64, 2, 2048, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_matches_ref(n, R, D, bd, dtype):
    from repro.core import gossip as G
    sched = G.theorem3_weight_schedule(n, 1 - 1 / n)
    ws = jnp.asarray(sched.stacked(0, R), jnp.float32)
    x = _rand(jax.random.key(3), (n, D), dtype)
    out = gossip_mix(ws, x, block_d=bd, interpret=True)
    want = ref.gossip_mix_ref(ws, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_gossip_mix_preserves_mean():
    """System invariant: doubly-stochastic mixing preserves the node mean."""
    from repro.core import gossip as G
    n, D = 16, 512
    sched = G.theorem3_weight_schedule(n, 0.8)
    ws = jnp.asarray(sched.stacked(0, 6), jnp.float32)
    x = _rand(jax.random.key(4), (n, D), jnp.float32)
    out = gossip_mix(ws, x, block_d=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out.mean(0)), np.asarray(x.mean(0)),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# decode attention (serve_step hot spot)
# ---------------------------------------------------------------------------

DECODE_CASES = [
    # (B, C, J, G, hd, window, filled, pos, bk)
    (2, 256, 2, 2, 64, 0, 256, 255, 128),     # full cache
    (1, 512, 1, 8, 64, 0, 300, 299, 128),     # partially filled (kpos = -1 tail)
    (2, 256, 2, 4, 128, 128, 256, 400, 64),   # ring buffer, window
    (1, 128, 4, 1, 32, 0, 128, 127, 128),     # MHA-ish
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    from repro.kernels.decode_attention import decode_attention
    B, C, J, G, hd, window, filled, pos, bk = case
    ks = jax.random.split(jax.random.key(5), 3)
    q = _rand(ks[0], (B, 1, J, G, hd), dtype)
    k = _rand(ks[1], (B, C, J, hd), dtype)
    v = _rand(ks[2], (B, C, J, hd), dtype)
    # kpos: ring semantics — absolute position of each slot, -1 when empty
    if window and pos >= C:
        base = pos - C + 1
        kpos = ((jnp.arange(C) - (base % C)) % C + base).astype(jnp.int32)
    else:
        kpos = jnp.where(jnp.arange(C) < filled, jnp.arange(C), -1).astype(jnp.int32)
    out = decode_attention(q, k, v, kpos, jnp.int32(pos), window=window,
                           block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, kpos, jnp.int32(pos),
                                    window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_decode_attention_matches_model_path():
    """The kernel must agree with the model's decode_attend (the jnp serve
    path used in the dry-run)."""
    from repro.kernels.decode_attention import decode_attention
    from repro.models import attention as mattn
    B, C, J, G, hd = 2, 128, 2, 3, 64
    ks = jax.random.split(jax.random.key(6), 3)
    q = _rand(ks[0], (B, 1, J, G, hd), jnp.float32)
    cache = {"k": _rand(ks[1], (B, C, J, hd), jnp.float32),
             "v": _rand(ks[2], (B, C, J, hd), jnp.float32),
             "kpos": jnp.where(jnp.arange(C) < 100, jnp.arange(C), -1).astype(jnp.int32)}
    want = mattn.decode_attend(q, cache, jnp.int32(99))
    got = decode_attention(q, cache["k"], cache["v"], cache["kpos"],
                           jnp.int32(99), block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(S=st.integers(1, 24), window=st.integers(0, 12),
       seed=st.integers(0, 2**16))
def test_property_cache_insert_matches_prefill(S, window, seed):
    """Ring-buffer cache update: inserting a sequence one token at a time
    (the decode path) must land the EXACT same cache as one cache_prefill
    of the full sequence — including the wrap case S > C, where only the
    last C positions survive at slot = pos % C."""
    import types

    from repro.models import attention as mattn
    KV, hd = 2, 4
    cfg = types.SimpleNamespace(window=window, num_kv_heads=KV, head_dim=hd)
    ks = jax.random.split(jax.random.key(seed), 2)
    k = _rand(ks[0], (1, S, KV, hd), jnp.float32)
    v = _rand(ks[1], (1, S, KV, hd), jnp.float32)

    via_prefill = mattn.cache_prefill(
        mattn.init_cache(cfg, 1, S, jnp.float32), k, v, jnp.arange(S))
    via_insert = mattn.init_cache(cfg, 1, S, jnp.float32)
    for pos in range(S):
        via_insert = mattn.cache_insert(
            via_insert, k[:, pos:pos + 1], v[:, pos:pos + 1], jnp.int32(pos))

    C = via_prefill["k"].shape[1]
    assert C == (min(window, S) if window else S)
    assert via_insert["k"].shape[1] == C
    for name in ("k", "v", "kpos"):
        np.testing.assert_array_equal(np.asarray(via_insert[name]),
                                      np.asarray(via_prefill[name]),
                                      err_msg=name)
    # ring semantics: exactly the last C positions survive, each at pos % C
    kpos = np.asarray(via_insert["kpos"])
    assert sorted(kpos) == list(range(S - C, S))
    assert all(kpos[p % C] == p for p in range(S - C, S))
    # and attending over either cache is the same computation
    q = _rand(jax.random.key(seed + 1), (1, 1, KV, 1, hd), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(mattn.decode_attend(q, via_insert, jnp.int32(S - 1),
                                       window=window)),
        np.asarray(mattn.decode_attend(q, via_prefill, jnp.int32(S - 1),
                                       window=window)))


def test_kernels_integrate_into_model_path():
    """cfg.use_pallas routes the transformer's attention through the Pallas
    kernels (interpret mode) and must match the jnp path end-to-end."""
    import dataclasses
    from repro import configs
    from repro.models import build, materialize_batch
    cfg = configs.get("qwen1.5-0.5b").reduced()
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    m, mk = build(cfg), build(cfg_k)
    params = m.init(jax.random.key(0), jnp.float32)
    batch = materialize_batch(cfg, 2, 32, jax.random.key(1), jnp.float32)
    l1, l2 = m.train_loss(params, batch), mk.train_loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    # serve path
    c1 = m.init_cache(2, 64, jnp.float32)
    c2 = mk.init_cache(2, 64, jnp.float32)
    lo1, c1 = m.prefill(params, batch, c1)
    lo2, c2 = mk.prefill(params, batch, c2)
    np.testing.assert_allclose(np.asarray(lo1), np.asarray(lo2), atol=2e-4)
    tok = jnp.argmax(lo1, -1).astype(jnp.int32)
    d1, _ = m.decode_step(params, tok, c1, jnp.int32(32))
    d2, _ = mk.decode_step(params, tok, c2, jnp.int32(32))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=2e-4)


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "recurrentgemma-2b"])
def test_linrec_kernel_integrates_into_recurrent_models(name):
    import dataclasses
    from repro import configs
    from repro.models import build, materialize_batch
    cfg = configs.get(name).reduced()
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    m, mk = build(cfg), build(cfg_k)
    params = m.init(jax.random.key(0), jnp.float32)
    batch = materialize_batch(cfg, 1, 128, jax.random.key(1), jnp.float32)
    l1, l2 = m.train_loss(params, batch), mk.train_loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-4)
