"""Checkpoint round-trip hardening.

msgpack_ckpt must preserve the FULL training state bit-exactly — including
bf16 tracker dtypes (whose numpy ``dtype.str`` is a raw void that used to
mangle the round-trip), local-optimizer state, and the round counter — and
``--restore`` must resume the schedule window at the correct ``t`` offset
(a federated schedule makes any phase error visible: an empty round taken
for the averaging round changes the trajectory).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import gossip
from repro.dist import steps as dsteps
from repro.optim import momentum

DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16, jnp.int32, jnp.int8,
          jnp.uint32, jnp.bool_]


def _roundtrip(tree, tmp_path, step=7):
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, tree, step=step)
    restored, k = load_checkpoint(path, tree)
    assert k == step
    return restored


def _assert_bit_exact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        assert x.shape == y.shape
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_leaf_dtype_roundtrip_bit_exact(dtype, tmp_path):
    key = jax.random.key(0)
    if jnp.dtype(dtype).kind == "f":
        leaf = jax.random.normal(key, (3, 5)).astype(dtype)
    elif jnp.dtype(dtype) == jnp.bool_:
        leaf = jax.random.normal(key, (3, 5)) > 0
    else:
        leaf = jax.random.randint(key, (3, 5), 0, 100).astype(dtype)
    tree = {"a": leaf, "nested": {"b": leaf[0]}}
    _assert_bit_exact(tree, _roundtrip(tree, tmp_path))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), dt_i=st.integers(0, len(DTYPES) - 1),
       ndim=st.integers(0, 3))
def test_property_any_leaf_roundtrips(seed, dt_i, ndim):
    import pathlib
    import tempfile

    dtype = DTYPES[dt_i]
    rng = np.random.default_rng(seed)
    shape = tuple(int(s) for s in rng.integers(1, 5, size=ndim))
    raw = rng.normal(size=shape) * 10
    leaf = jnp.asarray(raw).astype(dtype)
    tree = {"x": leaf}
    with tempfile.TemporaryDirectory() as td:
        _assert_bit_exact(tree, _roundtrip(tree, pathlib.Path(td),
                                           step=seed))


def test_trainstate_roundtrip_bf16_tracker_and_opt_state(tmp_path):
    """Full TrainState: bf16 h/g_prev, momentum opt_state, round counter —
    bit-exact after one real training step."""
    from test_engine import ToyModel, _toy_batch

    model = ToyModel()
    n = 4
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    init_s, warm, step = dsteps.make_train_step(
        model, None, algo="dsgd", gamma=0.1, R=1,
        aux_dtype=jnp.bfloat16, local_opt=momentum(0.9))
    state = init_s(jax.random.key(0), n, jnp.float32)
    state, _ = jax.jit(step)(state, _toy_batch(n, 1, 3, model.d, 1),
                             jnp.asarray(sched.stacked(0, 1)))
    assert jax.tree.leaves(state.h)[0].dtype == jnp.bfloat16
    restored = _roundtrip(state, tmp_path, step=1)
    _assert_bit_exact(state, restored)
    assert int(restored.step) == int(state.step)


def test_legacy_mangled_bf16_checkpoint_still_loads(tmp_path):
    """Checkpoints written before the name-based dtype format stored bf16 as
    the raw-void '<V2' string; loading one must resolve it back to bf16
    (same byte layout), and genuinely unknown dtypes must raise clearly."""
    import msgpack
    from repro.checkpoint.msgpack_ckpt import _dtype_from_name

    assert _dtype_from_name("<V2") == jnp.dtype(jnp.bfloat16)
    with pytest.raises(ValueError):
        _dtype_from_name("totally-unknown")

    leaf = jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)
    arr = np.asarray(leaf)
    path = str(tmp_path / "legacy.msgpack")
    payload = {b"step": 3, b"treedef": b"", b"leaves": [
        {b"dtype": arr.dtype.str.encode(),  # the legacy mangled form
         b"shape": list(arr.shape), b"data": arr.tobytes()}]}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload))
    restored, k = load_checkpoint(path, {"x": leaf})
    assert k == 3
    _assert_bit_exact({"x": leaf}, restored)


def test_hetero_stream_logits_computed_once():
    """The Dirichlet node marginals are cached — batch_at must not redo the
    host draw + device upload every step."""
    from repro.data.synthetic import TokenStream

    s = TokenStream(vocab_size=64, n_nodes=4, rounds=1, batch=1, seq=8,
                    seed=0, active_vocab=16, hetero_alpha=0.2)
    s.batch_at(0)
    first = s.node_token_logits()
    s.batch_at(1)
    assert s.node_token_logits() is first


def test_trainstate_roundtrip_bf16_compression_residuals(tmp_path):
    """Error-feedback residual state rides the checkpoint: bf16 res leaves
    for both the x and tracker streams survive bit-exactly, and the restored
    state continues the compressed trajectory identically."""
    from test_engine import ToyModel, _toy_batch

    from repro.core import compress

    model = ToyModel()
    n = 4
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    init_s, warm, step = dsteps.make_train_step(
        model, None, algo="mc_dsgt", gamma=0.1, R=2,
        aux_dtype=jnp.bfloat16,
        compression=compress.CompressionConfig(scheme="sign", group=4))
    Ws = jnp.asarray(sched.stacked(0, 2))
    batch = _toy_batch(n, 2, 3, model.d, 1)
    state = warm(init_s(jax.random.key(0), n, jnp.float32), batch)
    state, _ = jax.jit(step)(state, batch, Ws)
    res_x, res_h = state.res
    assert res_h is not None  # tracker stream has its own residual
    assert jax.tree.leaves(res_h)[0].dtype == jnp.bfloat16
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(res_x))
    restored = _roundtrip(state, tmp_path, step=1)
    _assert_bit_exact(state, restored)
    after_a, _ = jax.jit(step)(state, batch, Ws)
    after_b, _ = jax.jit(step)(restored, batch, Ws)
    _assert_bit_exact(after_a, after_b)


def test_restore_resumes_mid_warmup_with_scheme_still_disabled(tmp_path):
    """A --restore inside the compression warmup must keep gossiping at full
    precision until the ORIGINAL activation step: the gate compares the
    restored round counter, not steps-since-restore, so the continuation
    matches the uninterrupted compressed run step for step."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "resume_comp.msgpack")
    base = ["--arch", "qwen1.5-0.5b", "--preset", "reduced", "--nodes", "4",
            "--batch", "1", "--seq", "16", "--algo", "mc_dsgt", "--R", "2",
            "--compress", "sign", "--compress-group", "64",
            "--compress-warmup", "5"]
    full = train_main(base + ["--steps", "8"])
    _ = train_main(base + ["--steps", "3", "--checkpoint", ckpt])
    cont = train_main(base + ["--steps", "5", "--restore", ckpt])
    assert [h["step"] for h in cont] == [3, 4, 5, 6, 7]
    # steps 3-4 are still inside warmup; the scheme flips on at step 5.  A
    # gate keyed to steps-since-restore would compress steps 3-7 and
    # diverge immediately; dropping the residual would diverge at 5+.
    for h_full, h_cont in zip(full[3:], cont):
        np.testing.assert_allclose(h_full["loss"], h_cont["loss"], rtol=1e-6)
        np.testing.assert_allclose(h_full["consensus"], h_cont["consensus"],
                                   rtol=1e-4, atol=1e-7)


def test_restore_resumes_schedule_at_correct_t_offset(tmp_path):
    """--restore continuation == the uninterrupted run, step for step, on a
    federated schedule where the round phase matters (period 5: four empty
    rounds then the global average)."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "resume.msgpack")
    base = ["--arch", "qwen1.5-0.5b", "--preset", "reduced", "--nodes", "4",
            "--batch", "1", "--seq", "16", "--algo", "local_sgd",
            "--topology", "federated", "--gossip-impl", "auto"]
    full = train_main(base + ["--steps", "7"])
    _ = train_main(base + ["--steps", "4", "--checkpoint", ckpt])
    cont = train_main(base + ["--steps", "3", "--restore", ckpt])
    assert [h["step"] for h in cont] == [4, 5, 6]
    # steps 4-6 cross the period-5 averaging round: any phase offset error
    # in the restored t would diverge here
    for h_full, h_cont in zip(full[4:], cont):
        np.testing.assert_allclose(h_full["loss"], h_cont["loss"], rtol=1e-6)
        np.testing.assert_allclose(h_full["consensus"], h_cont["consensus"],
                                   rtol=1e-4, atol=1e-7)


def test_trainstate_roundtrip_delay_buffers_mid_window(tmp_path):
    """The stale-payload queues ride the checkpoint: saving mid-delay-window
    and restoring must continue the overlapped trajectory bit for bit (a
    dropped or reordered queue entry changes which payload the next mix
    consumes, so the very next step diverges)."""
    from test_engine import ToyModel, _toy_batch

    model = ToyModel()
    n, delay = 4, 2
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    init_s, warm, step = dsteps.make_train_step(
        model, None, algo="mc_dsgt", gamma=0.1, R=2,
        aux_dtype=jnp.bfloat16, delay=delay)
    Ws = jnp.asarray(sched.stacked(0, 2))
    batch = _toy_batch(n, 2, 3, model.d, 1)
    state = warm(init_s(jax.random.key(0), n, jnp.float32), batch)
    # three steps with delay=2: the queue holds one pre-save and one
    # post-warm payload — a genuinely mid-window snapshot
    for _ in range(3):
        state, _ = jax.jit(step)(state, batch, Ws)
    buf_x, buf_h = state.buf
    assert len(buf_x) == delay and len(buf_h) == delay
    assert jax.tree.leaves(buf_h[0])[0].dtype == jnp.bfloat16
    restored = _roundtrip(state, tmp_path, step=3)
    _assert_bit_exact(state, restored)
    after_a, _ = jax.jit(step)(state, batch, Ws)
    after_b, _ = jax.jit(step)(restored, batch, Ws)
    _assert_bit_exact(after_a, after_b)


def test_delay_mismatch_on_restore_warns_via_manifest(tmp_path):
    """A delay=0 checkpoint restored under a delay>0 spec is a scenario
    change: the manifest diff must flag ``algorithm.delay`` BEFORE the
    structural failure (the saved state has no queues; the delayed
    TrainState expects them, so the msgpack leaf counts cannot match)."""
    from repro import exp

    ckpt = str(tmp_path / "sync.msgpack")
    base = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16),
        algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=0.05, R=2),
        run=exp.RunSpec(steps=2, nodes=4, checkpoint=ckpt))
    exp.run(base, quiet=True)

    delayed = exp.with_field(
        exp.with_field(base, "run.restore", ckpt), "algorithm.delay", 1)
    delayed = exp.with_field(delayed, "run.checkpoint", None)
    with pytest.warns(UserWarning, match="algorithm.delay"):
        with pytest.raises(Exception):  # leaf-count mismatch: no queues saved
            exp.run(delayed, quiet=True)
