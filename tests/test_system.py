"""End-to-end system tests: the full decentralized training loop, the serve
loop, checkpointing, and the paper's §6 experiment in miniature."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import algorithms as alg
from repro.core import gossip
from repro.data import logreg_dataset, logreg_loss_and_grad, token_stream_for
from repro.dist import steps as dsteps
from repro.models import build


def test_decentralized_lm_training_loss_decreases(tmp_path):
    """MC-DSGT on a reduced qwen: loss must drop and node copies must stay
    in consensus; checkpoint save/restore must be exact."""
    cfg = configs.get("qwen1.5-0.5b").reduced()
    model = build(cfg)
    n, R = 4, 2
    sched = gossip.theorem3_weight_schedule(n, 0.5)
    stream = token_stream_for(cfg, n, R, 2, 32, seed=0, active_vocab=16)
    init_state, warm, step = dsteps.make_train_step(model, cfg, gamma=0.15, R=R)
    state = init_state(jax.random.key(0), n, jnp.float32)
    state = warm(state, stream.batch_at(0))
    step = jax.jit(step)

    losses = []
    t = 0
    for k in range(25):
        W = jnp.asarray(sched.stacked(t, 2 * R))
        state, m = step(state, stream.batch_at(k + 1), W)
        losses.append(float(m["loss"]))
        t += 2 * R
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]

    # consensus: all node copies close after training
    for leaf in jax.tree.leaves(state.x):
        xb = leaf.mean(0, keepdims=True)
        spread = float(jnp.abs(leaf - xb).max())
        scale = float(jnp.abs(leaf).max()) + 1e-9
        assert spread / scale < 0.05, spread / scale

    # checkpoint roundtrip
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, state, step=25)
    restored, k = load_checkpoint(path, state)
    assert k == 25
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_loop_greedy_decode():
    """Prefill + N greedy decode steps runs and is deterministic."""
    cfg = configs.get("recurrentgemma-2b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    from repro.models import materialize_batch
    batch = materialize_batch(cfg, 2, 16, jax.random.key(1), jnp.float32)
    outs = []
    for _ in range(2):
        cache = model.init_cache(2, 32, jnp.float32)
        logits, cache = model.prefill(params, batch, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [tok]
        for i in range(4):
            logits, cache = model.decode_step(params, tok, cache,
                                              jnp.int32(16 + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(tok)
        outs.append(jnp.concatenate(toks, axis=1))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_paper_section6_miniature():
    """The paper's §6 experiment in miniature: on a poorly-connected
    time-varying sun-shaped network with heterogeneous data, MC-DSGT's
    final ||grad f(x_bar)||^2 is at most DSGD's at equal budget."""
    n, d, m = 16, 32, 128
    beta = 1 - 1 / n
    H, y = logreg_dataset(n, m, d, seed=1)
    _, _, stoch, _, gnorm2 = logreg_loss_and_grad(rho=0.1)
    sched = gossip.theorem3_weight_schedule(n, beta)
    x0 = jnp.zeros((n, d))

    def grad_fn(xs, key):
        return stoch(xs, H, y, key, 16)

    budget = 384
    finals = {}
    for name, algo, steps in [("dsgd", alg.dsgd(0.4), budget),
                              ("mc", alg.mc_dsgt(0.8, R=4), budget // 8)]:
        _, hist = alg.run(algo, x0, grad_fn, sched, steps, jax.random.key(0),
                          eval_fn=lambda xb: gnorm2(xb, H, y),
                          eval_every=max(1, steps - 1))
        finals[name] = float(hist[-1][1])
    assert finals["mc"] <= finals["dsgd"] * 1.05, finals


def test_train_driver_cli(tmp_path):
    """The launch/train.py driver end-to-end with checkpointing."""
    from repro.launch.train import main as train_main
    ckpt = str(tmp_path / "drv.msgpack")
    hist = train_main(["--arch", "granite-moe-3b-a800m", "--preset", "reduced",
                       "--steps", "4", "--nodes", "4", "--beta", "0.75",
                       "--algo", "mc_dsgt", "--R", "2", "--gamma", "0.05",
                       "--batch", "2", "--seq", "32", "--checkpoint", ckpt])
    assert len(hist) == 4
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert os.path.exists(ckpt)
    # restore and continue
    hist2 = train_main(["--arch", "granite-moe-3b-a800m", "--preset",
                        "reduced", "--steps", "2", "--nodes", "4",
                        "--algo", "mc_dsgt", "--R", "2", "--batch", "2",
                        "--seq", "32", "--restore", ckpt])
    assert len(hist2) == 2
