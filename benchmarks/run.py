"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  figure2_mnist / figure2_covtype  — paper Figure 2 (§6): algorithm
      comparison on non-convex logistic regression, heterogeneous data,
      random sun-shaped graphs.  derived = final ||grad f||^2 ratio
      MC-DSGT / DSGD (< 1 reproduces the figure's ordering).
  table1_rate_T      — Table 1 row MC-DSGT: error ~ T^(-1/2) in the
      noise-dominated regime.  derived = fitted log-log slope.
  table1_speedup_n   — linear speedup term sigma/sqrt(nT).
      derived = error(n=4)/error(n=16) (theory: > 1 at matched T).
  theorem3_diameter  — Theorem 3: constructed effective distance == eq.(5).
      derived = max |construction - formula| over an (n, beta) grid.
  theorem4_progress  — Theorem 4 Instance 2: prog cap respected.
      derived = max prog / cap over the run (<= 1).
  kernel_*           — Pallas kernels (interpret mode) vs jnp oracle.
      derived = max |kernel - oracle|.
  compression_*      — compressed gossip (ISSUE 7): fused Pallas
      quantized_gossip_mix vs the unfused quantize-then-mix path, and
      convergence vs bandwidth per scheme (none/sign/int8) on the
      federated non-iid MC-DSGT scenario; writes BENCH_compression.json.
  engine_step_*      — throughput of the engine-built distributed step,
      one row per update rule (an ``exp.sweep`` over algorithm.name);
      also writes BENCH_engine.json.
  sim_*              — repro.sim wireless data path: mobility schedule
      resampling, channel degradation + weight repair, and gossip-plan
      restaging of the realized window; writes BENCH_sim.json.
  async_*            — overlapped gossip (ISSUE 8): step time with the
      stale-window double buffer on/off plus the jaxpr overlap proof,
      and delay ∈ {0,1,2} convergence on the Figure-2 scenario; writes
      BENCH_async.json.
  obs_*              — repro.obs measurement cost: in-jit metrics +
      recorder flushing vs the bare step (< 5% contract), and the
      telemetry per-round cache speedup; writes BENCH_obs.json.
  serve_*            — personalized fleet serving (ISSUE 10): continuous-
      batching prefill/decode throughput and p50/p95 request latency of
      repro.serve vs decode-slot count; writes BENCH_serve.json.
  roofline_summary   — reads experiments/dryrun/*.json if present.
      derived = #pairs whose dominant term is compute/memory/collective.

Scenario-parameterized benches (gossip_plan / engine_step / sim) generate
their rows from :class:`repro.exp.ExperimentSpec` grids via ``exp.sweep``
and emit through one :class:`BenchWriter`, so every BENCH_*.json shares the
schema {name, spec_hash, wall_ms, throughput, derived}.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUBSTR]
        [--json PATH]

With ``--json``, every family BENCH_*.json is additionally mirrored to the
repo root (the committed perf trajectory; see benchmarks/README.md for the
root-vs-baselines contract).
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

ALL_ROWS = []  # every row of the run, for the top-level --json dump

# Root-canonical BENCH contract: with --json, every family artifact a
# BenchWriter dumps is ALSO written to the repo root as BENCH_<name>.json —
# the committed perf trajectory — while benchmarks/baselines/ holds the
# reference copies check_regression.py gates against.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MIRROR_TO_ROOT = False


def _emit(name: str, us_per_call: float, derived, *, spec=None,
          throughput: float | None = None) -> dict:
    """Print the CSV line and append a row in the shared BENCH schema —
    ``name``, ``spec_hash`` (the scenario's :func:`repro.exp.spec_hash`,
    None for non-spec'd micro-benches), ``wall_ms`` per call,
    ``throughput`` (calls/s), free-form ``derived``."""
    if spec is not None:
        from repro import exp
        spec_hash = exp.spec_hash(spec)
    else:
        spec_hash = None
    if throughput is None and us_per_call > 0:
        throughput = round(1e6 / us_per_call, 2)
    rec = {"name": name, "spec_hash": spec_hash,
           "wall_ms": round(us_per_call / 1000, 4),
           "throughput": throughput, "derived": derived}
    ALL_ROWS.append(rec)
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    return rec


class BenchWriter:
    """Collects the rows of one bench family (same schema as :func:`_emit`)
    so they can be dumped to that family's BENCH_*.json artifact."""

    def __init__(self):
        self.rows = []

    def row(self, name: str, us_per_call: float, derived, *,
            spec=None, throughput: float | None = None) -> None:
        self.rows.append(_emit(name, us_per_call, derived, spec=spec,
                               throughput=throughput))

    def dump(self, path: str) -> None:
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=1)
        print(f"wrote {path}", file=sys.stderr)
        if MIRROR_TO_ROOT:
            root = os.path.join(REPO_ROOT, os.path.basename(path))
            with open(root, "w") as f:
                json.dump(self.rows, f, indent=1)
            print(f"wrote {root}", file=sys.stderr)


def record(name: str, us_per_call: float, derived) -> None:
    _emit(name, us_per_call, derived)


def _timed(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


# ---------------------------------------------------------------------------
# Figure 2
# ---------------------------------------------------------------------------

def bench_figure2(quick: bool) -> None:
    from repro.configs.logreg_paper import COVTYPE, MNIST
    from examples import paper_figure2 as f2

    steps = 160 if quick else 480
    for lc, tag in [(MNIST, "figure2_mnist"), (COVTYPE, "figure2_covtype")]:
        t0 = time.time()
        curves = f2.run_setup(lc, steps, gamma=0.5)
        us = (time.time() - t0) * 1e6
        final = {k: v[-1][1] for k, v in curves.items()}
        mc = min(v for k, v in final.items() if k.startswith("mc"))
        record(tag, us / steps, round(mc / max(final["dsgd"], 1e-12), 4))


# ---------------------------------------------------------------------------
# Table 1: rate scaling
# ---------------------------------------------------------------------------

def _run_mc(n, beta, T, gamma, R, sigma, seed=0, d=32):
    from repro.core import algorithms as alg, gossip
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(rng.normal(size=(n, d)))

    def grad_fn(xs, key):
        return xs - centers + sigma * jax.random.normal(key, xs.shape)

    def eval_fn(xbar):
        return jnp.sum((xbar - centers.mean(0)) ** 2)

    sched = gossip.theorem3_weight_schedule(n, beta)
    algo = alg.mc_dsgt(gamma, R=R)
    steps = max(2, T // (2 * R))
    _, hist = alg.run(algo, jnp.zeros((n, d)), grad_fn, sched, steps,
                      jax.random.key(seed), eval_fn=eval_fn,
                      eval_every=max(1, steps - 1))
    return float(hist[-1][1])


def bench_table1_rate_T(quick: bool) -> None:
    Ts = [64, 256, 1024] if quick else [64, 256, 1024, 4096]
    n, beta, R, sigma = 8, 0.5, 2, 2.0
    errs = []
    t0 = time.time()
    for T in Ts:
        gamma = min(0.5, 2.0 / math.sqrt(T))  # ~ 1/sqrt(T) schedule
        e = np.mean([_run_mc(n, beta, T, gamma, R, sigma, seed=s)
                     for s in range(3)])
        errs.append(e)
    us = (time.time() - t0) * 1e6
    slope = np.polyfit(np.log(Ts), np.log(np.maximum(errs, 1e-12)), 1)[0]
    record("table1_rate_T", us / len(Ts), round(float(slope), 3))


def bench_table1_speedup_n(quick: bool) -> None:
    T, beta, R, sigma = 512, 0.5, 2, 2.0
    t0 = time.time()
    errs = {}
    for n in (4, 16):
        errs[n] = np.mean([_run_mc(n, beta, T, 0.05, R, sigma, seed=s)
                           for s in range(3)])
    us = (time.time() - t0) * 1e6
    record("table1_speedup_n", us / 2,
           round(errs[4] / max(errs[16], 1e-12), 3))


# ---------------------------------------------------------------------------
# Theorem 3 / Theorem 4
# ---------------------------------------------------------------------------

def bench_r_ablation(quick: bool) -> None:
    """Theorem 6 / eq. (41): the optimal consensus-round count R grows with
    1/(1-beta).  Heterogeneous-curvature quadratics (consensus error feeds
    the bias, so multi-consensus pays off) on a well- vs poorly-connected
    schedule.  derived = bestR at each beta (expected: larger at large
    beta)."""
    from repro.core import algorithms as alg, gossip
    n, d, T, sigma = 16, 16, 768, 1.0
    rng = np.random.default_rng(0)
    centers = jnp.asarray(rng.normal(size=(n, d)) * 4.0)
    hess = jnp.asarray(rng.uniform(0.2, 2.0, size=(n, d)))
    xstar = (hess * centers).mean(0) / hess.mean(0)

    def grad_fn(xs, key):
        return hess * (xs - centers) + sigma * jax.random.normal(key, xs.shape)

    def eval_fn(xb):
        return jnp.sum((xb - xstar) ** 2)

    t0 = time.time()
    gains = {}
    Rs = [1, 2]
    for beta in (0.5, 1 - 1 / n):
        sched = gossip.theorem3_weight_schedule(n, beta)
        errs = {}
        for R in Rs:
            algo = alg.mc_dsgt(0.3, R=R)
            steps = max(2, T // (2 * R))
            fin = []
            for seed in range(3):
                _, hist = alg.run(algo, jnp.zeros((n, d)), grad_fn, sched,
                                  steps, jax.random.key(seed),
                                  eval_fn=eval_fn, eval_every=max(1, steps - 1))
                fin.append(hist[-1][1])
            errs[R] = float(np.mean(fin))
        gains[beta] = errs[1] / max(errs[2], 1e-12)  # R=1 -> R=2 improvement
    us = (time.time() - t0) * 1e6
    # Theorem 6 signature: multi-consensus helps MORE on poorly connected
    # networks -> the gain ratio should exceed 1
    record("table1_R_ablation", us / (2 * len(Rs)),
           f"gainR2(beta={1 - 1 / n:.3f})={gains[1 - 1 / n]:.2f}x"
           f"|gainR2(0.5)={gains[0.5]:.2f}x")


def bench_theorem3(quick: bool) -> None:
    from repro.core import topology as topo
    t0 = time.time()
    worst = 0
    cases = 0
    for n in (8, 16, 32):
        for bfrac in (0.0, 0.3, 0.6, 0.9, 1.0):
            beta = bfrac * (1 - 1 / n)
            size = max(1, math.ceil(n / 4))
            I1 = tuple(range(size))
            I2 = tuple(range(n - size, n))
            sched = topo.sun_shaped_schedule(n, beta, avoid=I1 + I2)
            got = topo.effective_distance(sched, I1, I2, period=sched.period)
            want = topo.theorem3_distance_formula(n, beta, size, size)
            worst = max(worst, abs(got - want))
            cases += 1
    us = (time.time() - t0) * 1e6
    record("theorem3_diameter", us / cases, worst)


def bench_theorem4(quick: bool) -> None:
    from repro.core import algorithms as alg, gossip, lower_bound as lb
    from repro.core import topology as topo
    n, beta, T = 16, 1 - 1 / 16, 64
    inst = lb.make_instance2(L=1.0, Delta=10.0, n=n, beta=beta, T=T)
    I = inst.set1 + inst.set2
    graphs = topo.sun_shaped_schedule(n, beta, avoid=I)
    dist = topo.effective_distance(graphs, inst.set1, inst.set2,
                                   period=graphs.period)
    wsched = gossip.theorem3_weight_schedule(n, beta, avoid=I)

    def grad_fn(xs, key):
        return inst.grad_stacked(xs)

    algo = alg.dsgt(gamma=0.3)
    state = algo.init(jnp.zeros((n, inst.d)))
    state = alg.warm_start(algo, state, grad_fn, jax.random.key(0))
    step = jax.jit(algo.step, static_argnums=1)
    t0 = time.time()
    worst_ratio, t = 0.0, 0
    for k in range(T // 2):
        Ws = jnp.asarray(wsched.stacked(t, 2))
        state = step(state, grad_fn, Ws, jax.random.key(k))
        t += 2
        cap = t // dist + 1
        mp = max(int(lb.prog(state.x[i])) for i in range(n))
        worst_ratio = max(worst_ratio, mp / cap)
    us = (time.time() - t0) * 1e6
    record("theorem4_progress", us / (T // 2), round(worst_ratio, 3))


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

def bench_kernels(quick: bool) -> None:
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.gossip_matmul import gossip_mix
    from repro.kernels.linear_recurrence import linear_recurrence
    from repro.core import gossip as G

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, interpret=True))
    us, out = _timed(f, q, k, v)
    err = float(jnp.abs(out - ref.attention_ref(q, k, v)).max())
    record("kernel_flash_attention", us, f"{err:.2e}")

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 256, 256)))
    b = jax.random.normal(ks[1], (1, 256, 256))
    f = jax.jit(lambda a, b: linear_recurrence(a, b, interpret=True))
    us, out = _timed(f, a, b)
    err = float(jnp.abs(out[0] - ref.linear_recurrence_ref(a, b)[0]).max())
    record("kernel_linear_recurrence", us, f"{err:.2e}")

    from repro.kernels.decode_attention import decode_attention
    q1 = jax.random.normal(ks[0], (2, 1, 2, 4, 64))
    kc = jax.random.normal(ks[1], (2, 512, 2, 64))
    vc = jax.random.normal(ks[2], (2, 512, 2, 64))
    kpos = jnp.arange(512, dtype=jnp.int32)
    f = jax.jit(lambda q, k, v: decode_attention(q, k, v, kpos,
                                                 jnp.int32(511),
                                                 interpret=True))
    us, out = _timed(f, q1, kc, vc)
    err = float(jnp.abs(out - ref.decode_attention_ref(
        q1, kc, vc, kpos, jnp.int32(511))).max())
    record("kernel_decode_attention", us, f"{err:.2e}")

    sched = G.theorem3_weight_schedule(16, 0.9)
    ws = jnp.asarray(sched.stacked(0, 4), jnp.float32)
    x = jax.random.normal(ks[2], (16, 4096))
    f = jax.jit(lambda w, x: gossip_mix(w, x, interpret=True))
    us, out = _timed(f, ws, x)
    err = float(jnp.abs(out - ref.gossip_mix_ref(ws, x)).max())
    record("kernel_gossip_matmul", us, f"{err:.2e}")


# ---------------------------------------------------------------------------
# Compressed gossip: fused kernel vs unfused, convergence vs bandwidth
# ---------------------------------------------------------------------------

def bench_compression(quick: bool) -> None:
    """The compression-axis headline (ISSUE 7).  Rows:

    ``compression_fused_kernel`` — the fused Pallas
        ``quantized_gossip_mix`` (quantize -> mix -> dequantize -> residual
        for all R rounds in one pass) vs the unfused
        quantize-then-``gossip_mix`` path (R separate kernel launches with
        a full state round-trip between them).  derived = unfused us,
        speedup (> 1 = fused wins), and max |fused - unfused| (~0: both
        paths share the kernels/ref.py quantization math).
    ``compression_{none,sign,int8}`` — an ``exp.sweep`` over
        ``compression.scheme`` on the federated non-iid MC-DSGT scenario
        (error feedback on): final train loss vs the uncompressed run,
        nominal bytes/round from the manifest accounting, and measured
        cumulative wire bytes from the telemetry recorder.  The headline
        contract: sign stays within 10% of the uncompressed final loss at
        <= 1/8 the bytes/round.
    Writes experiments/bench/BENCH_compression.json (mirrored to the repo
    root under --json — the committed perf trajectory)."""
    import tempfile

    from repro import exp
    from repro.core import compress, gossip
    from repro.kernels import ops, ref

    w = BenchWriter()

    # fused vs unfused kernel wall time
    n, R = 16, 4
    D = 65536 if quick else 1 << 18
    sched = gossip.theorem3_weight_schedule(n, 0.9)
    ws = jnp.asarray(sched.stacked(0, R), jnp.float32)
    x = jax.random.normal(jax.random.key(0), (n, D))
    res = jnp.zeros_like(x)

    @jax.jit
    def fused(ws, x, res):
        return ops.quantized_gossip_mix(ws, x, res, scheme="sign",
                                        use_pallas=True)

    @jax.jit
    def unfused(ws, x, res):
        for r in range(R):
            deq, err = ref.quantize_dequantize_ref(x + res, scheme="sign")
            res = err
            x = ops.gossip_mix(ws[r:r + 1], deq, use_pallas=True)
        return x, res

    us_f, out_f = _timed(fused, ws, x, res)
    us_u, out_u = _timed(unfused, ws, x, res)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(out_f, out_u))
    w.row("compression_fused_kernel", us_f,
          f"unfused_us={us_u:.1f}|speedup={us_u / max(us_f, 1e-9):.2f}x"
          f"|rounds={R}|D={D}|err={err:.1e}")

    # convergence vs bandwidth per scheme (the perf/quality headline)
    steps = 6 if quick else 12
    base = exp.from_dict({
        "algorithm": {"name": "mc_dsgt", "R": 2, "gamma": 0.1},
        "data": {"batch": 2, "seq": 32, "hetero_alpha": 0.3},
        "topology": {"kind": "federated", "local_steps": 4},
        "run": {"steps": steps, "nodes": 4, "log_every": steps}})
    finals = {}
    with tempfile.TemporaryDirectory() as td:
        for spec in exp.sweep(base, {"compression.scheme":
                                     list(exp.COMPRESSIONS)}):
            scheme = spec.compression.scheme
            spec = exp.with_field(spec, "run.telemetry",
                                  os.path.join(td, f"{scheme}.json"))
            t0 = time.time()
            r = exp.run(spec, quiet=True)
            us = (time.time() - t0) * 1e6 / steps
            loss = float(r.history[-1]["loss"])
            finals[scheme] = loss
            bpr = compress.payload_bytes(r.built.state_dim, scheme,
                                         spec.compression.group)
            bpr0 = compress.payload_bytes(r.built.state_dim, "none")
            w.row(f"compression_{scheme}", us,
                  f"final_loss={loss:.4f}"
                  f"|vs_none={loss / finals['none']:.4f}"
                  f"|bytes_per_round={bpr}"
                  f"|bytes_vs_none={bpr / bpr0:.4f}"
                  f"|wire_bytes_total={r.telemetry.bytes_total}",
                  spec=spec, throughput=round(1e6 / us, 2))
    w.dump("experiments/bench/BENCH_compression.json")


# ---------------------------------------------------------------------------
# Gossip planning: dense einsum vs structured lowering, per topology
# ---------------------------------------------------------------------------

def bench_gossip_plan(quick: bool) -> None:
    """Times one full schedule period of multi-consensus on an (n, D) state:
    the dense einsum stack vs the structured GossipPlan lowering the auto
    dispatcher picks, one row per topology of an ``exp.sweep`` grid.
    derived = auto path us, speedup, the plan's round kinds, and
    max |dense - auto| (must be ~0).  Writes BENCH_gossip_plan.json."""
    from repro import exp
    from repro.core import algorithms as alg
    from repro.dist.collectives import stage_plan

    n = 16
    D = 65536 if quick else 1 << 20
    x = jax.random.normal(jax.random.key(0), (n, D))
    base = exp.ExperimentSpec(topology=exp.TopologySpec(beta=0.75),
                              run=exp.RunSpec(nodes=n))
    w = BenchWriter()
    for spec in exp.sweep(base, {"topology.kind": [
            "sun", "one-peer-exp", "federated", "complete",
            "random-matching", "erdos-renyi"]}):
        sched = exp.build_topology(spec.topology, n, seed=spec.run.seed)
        P = sched.period
        plan = sched.plan(0, P)
        Ws = jnp.asarray(sched.stacked(0, P))
        tensors = stage_plan(plan)
        mixer = alg.make_plan_mixer(plan, mode="static")
        dense_f = jax.jit(lambda Ws, x: alg.multi_consensus(Ws, x))
        auto_f = jax.jit(lambda T, x: mixer(T, 0, P, x))
        us_d, out_d = _timed(dense_f, Ws, x)
        us_a, out_a = _timed(auto_f, tensors, x)
        err = float(jnp.abs(out_d - out_a).max())
        kinds = ",".join(sorted(set(plan.kinds)))
        w.row(f"gossip_plan_{spec.topology.kind}", us_d,
              f"auto_us={us_a:.1f}|speedup={us_d / max(us_a, 1e-9):.2f}x"
              f"|kinds={kinds}|err={err:.1e}", spec=spec)
    w.dump("experiments/bench/BENCH_gossip_plan.json")


# ---------------------------------------------------------------------------
# repro.sim: mobility resampling, fault realization, plan restaging
# ---------------------------------------------------------------------------

def bench_sim(quick: bool) -> None:
    """Throughput of the wireless-simulation data path, per stage: mobility
    schedule resampling (unit-disk adjacency rounds), channel+repair
    realization (ideal W -> masked -> repaired), and plan restaging
    (WeightSchedule.plan + stage_plan of the realized window).  Every stage
    is keyed by the scenario spec it realizes.  derived = rounds/s (and the
    realized plan's kind counts for the restage row).  Also writes
    experiments/bench/BENCH_sim.json — a CI artifact."""
    from repro import exp
    from repro.dist.collectives import stage_plan
    from repro.sim import (random_geometric_schedule,
                           random_waypoint_schedule,
                           realize_weight_schedule)

    n = 16
    rounds = 64 if quick else 256
    base = exp.ExperimentSpec(run=exp.RunSpec(nodes=n))
    w = BenchWriter()

    # time the RAW topology resampling (per-round unit-disk adjacency
    # draws) — exp.build_topology would pre-materialize the whole window
    # outside the timed region and we'd be benchmarking tuple indexing
    _mobility = {"geometric-mobility": random_geometric_schedule,
                 "waypoint-mobility": random_waypoint_schedule}
    for spec in exp.sweep(base, {"topology.kind": list(_mobility)}):
        sched = _mobility[spec.topology.kind](
            n, spec.topology.radius, seed=spec.run.seed)
        t0 = time.time()
        for t in range(rounds):
            sched(t)
        us = (time.time() - t0) * 1e6 / rounds
        tag = spec.topology.kind.split("-")[0]
        w.row(f"sim_resample_{tag}", us, f"rounds_per_s={1e6 / us:.0f}",
              spec=spec)

    wspec = exp.with_overrides(base, {
        "topology.kind": "waypoint-mobility",
        "channel.link_drop": 0.2, "channel.burst_loss": 0.1})
    ideal = exp.build_topology(wspec.topology, n, horizon=rounds,
                               seed=wspec.run.seed)
    models = exp.build_channel_models(wspec.channel, wspec.run.seed)
    t0 = time.time()
    realized = realize_weight_schedule(ideal, models, rounds=rounds)
    us = (time.time() - t0) * 1e6 / rounds
    w.row("sim_realize_channel_repair", us, f"rounds_per_s={1e6 / us:.0f}",
          spec=wspec)

    t0 = time.time()
    plan = realized.plan(0, rounds)
    tensors = stage_plan(plan)
    jax.block_until_ready(tensors)
    us = (time.time() - t0) * 1e6 / rounds
    kinds = "+".join(f"{plan.kinds.count(k)}x{k}"
                     for k in dict.fromkeys(plan.kinds))
    epr = []
    for rd in plan.rounds:
        off = np.abs(rd.W) > 1e-12
        np.fill_diagonal(off, False)
        epr.append(int(off.sum()))
    w.row("sim_plan_restage", us,
          f"rounds_per_s={1e6 / us:.0f}|kinds={kinds}"
          f"|edges_per_round={np.mean(epr):.0f}", spec=wspec)

    w.dump("experiments/bench/BENCH_sim.json")


# ---------------------------------------------------------------------------
# Sparse scenario engine: staging vs n, dense comparison, segment-sum mixer
# ---------------------------------------------------------------------------

def bench_sparse(quick: bool) -> None:
    """Throughput of the sparse scenario engine per stage and node count:
    realize (sampled cohort + unit-disk + Metropolis edges), repair
    (per-edge channel masks), and restage (SparseGossipPlan + padded
    tensors) at n in {64, 1k, 10k, 100k} with a fixed per-round cohort —
    the headline claim is near-flat us/round as n grows, because every
    stage is O(edges) = O(k^2), never O(n^2).  The dense pipeline runs the
    SAME sampled rounds at the n where (n, n) materialization is feasible,
    as the baseline it escapes.  A final pair of rows prices one edge-list
    gossip round through the jnp segment-sum reference vs the fused Pallas
    kernel (derived = max |fused - unfused|).  Also writes
    experiments/bench/BENCH_sparse.json — a CI artifact."""
    from repro import exp, sparse
    from repro.core import gossip, topology as topo
    from repro.kernels import ops as kops
    from repro.sim import channel as sim_channel

    k = 64
    rounds = 8 if quick else 32
    sizes = (64, 1_000, 10_000, 100_000)
    dense_sizes = (64, 1_000)
    w = BenchWriter()

    for n in sizes:
        kk = min(k, n)
        spec = exp.ExperimentSpec(
            model=exp.ModelRef(kind="logreg"),
            topology=exp.TopologySpec(kind="random-sampled", sample_k=kk),
            channel=exp.ChannelSpec(link_drop=0.2),
            run=exp.RunSpec(nodes=n, gossip_impl="auto"))
        models = exp.build_channel_models(spec.channel, spec.run.seed)

        t0 = time.time()
        ideal = sparse.sampled_weight_schedule(n, kk, horizon=rounds)
        us = (time.time() - t0) * 1e6 / rounds
        epr = float(ideal.edges_per_round.mean())
        w.row(f"sparse_realize_n{n}", us,
              f"rounds_per_s={1e6 / us:.0f}|edges_per_round={epr:.0f}",
              spec=spec)

        t0 = time.time()
        real = sparse.realize_sparse_schedule(ideal, models)
        us = (time.time() - t0) * 1e6 / rounds
        w.row(f"sparse_repair_n{n}", us,
              f"rounds_per_s={1e6 / us:.0f}|edges_per_round="
              f"{real.edges_per_round.mean():.0f}", spec=spec)

        t0 = time.time()
        plan = real.plan(validate=False)
        tensors = {key: jnp.asarray(v) for key, v in plan.tensors().items()}
        jax.block_until_ready(tensors)
        us = (time.time() - t0) * 1e6 / rounds
        kinds = "+".join(f"{plan.kinds.count(kd)}x{kd}"
                         for kd in dict.fromkeys(plan.kinds))
        w.row(f"sparse_restage_n{n}", us,
              f"rounds_per_s={1e6 / us:.0f}|kinds={kinds}", spec=spec)

        if n in dense_sizes:
            # the dense pipeline on the SAME realized rounds: materialize
            # (n, n) matrices, classify, and lower through the dense planner
            t0 = time.time()
            mats = [real(t) for t in range(rounds)]
            ws = gossip.WeightSchedule(
                tuple(mats),
                tuple(topo.classify_adjacency(np.abs(M) > 1e-12)
                      for M in mats))
            dplan = ws.plan(0, rounds, sparse=False)
            jax.block_until_ready(
                {key: jnp.asarray(v) for key, v in dplan.tensors().items()})
            us = (time.time() - t0) * 1e6 / rounds
            w.row(f"sparse_dense_path_n{n}", us,
                  f"rounds_per_s={1e6 / us:.0f}", spec=spec)

    # fused vs unfused segment-sum mix of one realized round (n=1k cohort)
    rd = sparse.SampledMobilitySchedule(1_000, min(256, k * 4)).round(0)
    plan1 = sparse.SparseGossipPlan.from_rounds([rd])
    tt = plan1.tensors()
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((1_000, 256)), jnp.float32)
    args = tuple(jnp.asarray(tt[key][0])
                 for key in ("esrc", "edst", "ew", "seg", "slots"))
    us_ref, out_ref = _timed(
        lambda: kops.sparse_gossip_mix(x, *args, use_pallas=False))
    us_pal, out_pal = _timed(
        lambda: kops.sparse_gossip_mix(x, *args, use_pallas=True))
    err = float(jnp.max(jnp.abs(out_ref - out_pal)))
    w.row("sparse_mix_segment_unfused", us_ref,
          f"edges={rd.edges}|dim=256")
    w.row("sparse_mix_segment_fused", us_pal,
          f"edges={rd.edges}|dim=256|max_err={err:.2e}")
    assert err < 1e-4, f"fused segment mix diverged: {err}"

    w.dump("experiments/bench/BENCH_sparse.json")


# ---------------------------------------------------------------------------
# Engine step throughput (one row per update rule)
# ---------------------------------------------------------------------------

def bench_engine_step(quick: bool) -> None:
    """Throughput of the engine-built distributed train step for EVERY
    update rule the single-source engine defines — an ``exp.sweep`` over
    ``algorithm.name`` on the reduced qwen config with dense gossip, each
    row realized via ``exp.build``.  derived = steps/s and the rule's
    gossip rounds per step.  Also writes
    experiments/bench/BENCH_engine.json — the BENCH trajectory artifact CI
    uploads."""
    from repro import exp
    from repro.dist import steps as dsteps

    n = 4
    base = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16, active_vocab=16),
        topology=exp.TopologySpec(kind="sun", beta=0.5),
        run=exp.RunSpec(nodes=n))
    w = BenchWriter()
    for spec in exp.sweep(base, {"algorithm.name": list(exp.ALGORITHMS)}):
        spec = exp.with_field(spec, "algorithm.R",
                              2 if spec.algorithm.name == "mc_dsgt" else 1)
        b = exp.build(spec)
        init_s, warm, step = dsteps.make_train_step(
            b.model, b.cfg, algo=spec.algorithm.name,
            gamma=spec.algorithm.gamma, R=b.rule.R)
        state = warm(init_s(jax.random.key(spec.run.seed), n, jnp.float32),
                     b.stream.batch_at(0))
        W = jnp.asarray(b.schedule.stacked(0, b.wps))
        us, _ = _timed(jax.jit(step), state, b.stream.batch_at(1), W)
        w.row(f"engine_step_{spec.algorithm.name}", us,
              f"steps_per_s={1e6 / max(us, 1e-9):.1f}|wps={b.wps}",
              spec=spec)
    w.dump("experiments/bench/BENCH_engine.json")


# ---------------------------------------------------------------------------
# Async overlapped gossip (stale-window delay)
# ---------------------------------------------------------------------------

def bench_async(quick: bool) -> None:
    """The overlapped-gossip runtime (stale-window delay).  Two row groups:

    ``async_step_*`` — steady-state step time of the distributed train
        step on the BENCH_engine LM scenario (reduced qwen, 4 nodes, sun
        schedule): dsgd and mc_dsgt synchronous, then mc_dsgt with
        ``delay=1`` (the double-buffered overlap path).  Each delayed row's
        derived carries the :func:`repro.obs.overlap_report` verdict —
        the jaxpr-level proof that no obs_mix op consumes an obs_grad
        output — and ``async_overlap_ratio`` reports the headline
        mc_dsgt(delay=1)/dsgd ratio (contract: <= 1.3 with overlap on;
        note XLA:CPU schedules conservatively, so the wall-clock win is
        a TPU property — the ratio row still tracks the trend and the
        overlap_ok flag is backend-independent).
    ``async_converge_delay{0,1,2}`` — the Figure-2 scenario (non-convex
        logistic regression, Dirichlet-heterogeneous data, random sun
        graphs, mc_dsgt R=2): final loss under each staleness window.
        derived = final loss and ``delta_frac``, the |final - sync final|
        as a fraction of the synchronous run's total descent (contract:
        <= 2%).  Fixed length by design — staleness x step-size trades
        off like momentum, so the comparison is at a matched budget.
    Writes experiments/bench/BENCH_async.json."""
    from repro import exp
    from repro.dist import steps as dsteps
    from repro.obs import overlap_report

    n = 4
    lm = exp.ExperimentSpec(
        data=exp.DataSpec(batch=1, seq=16, active_vocab=16),
        topology=exp.TopologySpec(kind="sun", beta=0.5),
        run=exp.RunSpec(nodes=n))
    w = BenchWriter()
    times, reps = {}, {}
    for algo, delay in [("dsgd", 0), ("mc_dsgt", 0), ("mc_dsgt", 1)]:
        spec = exp.with_overrides(lm, {
            "algorithm.name": algo, "algorithm.delay": delay,
            "algorithm.R": 2 if algo == "mc_dsgt" else 1})
        b = exp.build(spec)
        init_s, warm, step = dsteps.make_train_step(
            b.model, b.cfg, algo=algo, gamma=spec.algorithm.gamma,
            R=b.rule.R, delay=delay)
        state = warm(init_s(jax.random.key(spec.run.seed), n, jnp.float32),
                     b.stream.batch_at(0))
        W = jnp.asarray(b.schedule.stacked(0, b.wps))
        batch = b.stream.batch_at(1)
        us, _ = _timed(jax.jit(step), state, batch, W)
        rep = overlap_report(step, state, batch, W)  # un-jitted: real eqns
        times[(algo, delay)] = us
        reps[(algo, delay)] = rep
        w.row(f"async_step_{algo}_delay{delay}", us,
              f"steps_per_s={1e6 / max(us, 1e-9):.1f}"
              f"|overlap_ok={rep['overlapped']}", spec=spec)
    ratio = times[("mc_dsgt", 1)] / max(times[("dsgd", 0)], 1e-9)
    w.row("async_overlap_ratio", times[("mc_dsgt", 1)],
          f"ratio_vs_dsgd={ratio:.2f}|target=1.3"
          f"|overlap_ok={reps[('mc_dsgt', 1)]['overlapped']}")

    steps_c, gamma = (40, 0.05) if quick else (60, 0.05)
    base_spec = exp.ExperimentSpec(
        model=exp.ModelRef(kind="logreg", d=16, m=256),
        data=exp.DataSpec(batch=8, hetero_alpha=0.5),
        algorithm=exp.AlgorithmSpec(name="mc_dsgt", gamma=gamma, R=2),
        topology=exp.TopologySpec(kind="random-sun"),
        run=exp.RunSpec(steps=steps_c, nodes=8))
    finals = {}
    for delay in (0, 1, 2):
        spec = exp.with_field(base_spec, "algorithm.delay", delay)
        t0 = time.time()
        hist = exp.run(spec, quiet=True).history
        us = (time.time() - t0) * 1e6 / steps_c
        init, final = float(hist[0][1]), float(hist[-1][1])
        finals[delay] = (init, final)
        descent = max(finals[0][0] - finals[0][1], 1e-12)
        delta = abs(final - finals[0][1]) / descent
        w.row(f"async_converge_delay{delay}", us,
              f"final={final:.5f}|delta_frac={delta:.4f}|target=0.02",
              spec=spec)
    w.dump("experiments/bench/BENCH_async.json")


# ---------------------------------------------------------------------------
# Observability overhead (repro.obs)
# ---------------------------------------------------------------------------

def bench_obs(quick: bool) -> None:
    """Cost of measuring a run.  Two rows:

    ``obs_run_overhead`` — steady-state per-step wall time of the shared
        driver loop on the quickstart workload (logreg d=64 m=256, 16
        nodes, mc_dsgt R=4 over the theorem-3 sun schedule) at three
        observability levels: ``bare`` (no obs), ``injit`` (the in-jit
        metric scalars only), and ``full`` (ObsRecorder + phase tracer +
        gap tracker + JSONL sink at every=10).  The loop is pre-compiled
        and timed over interleaved repetitions (median), so compile and
        dataset costs never enter — unlike wall-clocking ``exp.run``,
        which re-jits per call and drowns a us-scale delta in ~1s of
        compile noise.  derived = in-jit and full overhead fractions.
        The PR's contract (< 5% at every=10) targets the hot-path cost:
        with >= 2 cores the background flusher overlaps the drain work
        (host transfer + json + gap update, ~15 us/step amortized); on a
        single-core container everything serializes onto one core and
        the full fraction reads higher — ``ncores`` is recorded so the
        number can be judged in context.
    ``obs_telemetry_cache`` — TelemetryRecorder's per-record window
        materialization (float64 stack + adjacency + kind counts of the
        trailing rounds) with the per-round cache vs the uncached
        per-call re-stack, sliding over a realized wireless schedule.
        derived = speedup (O(window) -> O(new rounds) per call) and the
        full ``record()`` time for context.
    Writes experiments/bench/BENCH_obs.json."""
    import statistics
    import tempfile

    from repro import exp
    from repro.core import algorithms as alg
    from repro.core import driver, engine
    from repro.data.synthetic import logreg_dataset, logreg_loss_and_grad
    from repro.obs import EventLog, GapTracker, ObsRecorder, Tracer
    from repro.sim import realize_weight_schedule
    from repro.sim.telemetry import TelemetryRecorder

    # the quickstart cell: mc_dsgt R=4 gamma=0.4 on a beta=.9375 sun
    base = exp.from_dict({
        "model": {"kind": "logreg", "d": 64, "m": 256, "rho": 0.1},
        "data": {"batch": 16},
        "algorithm": {"name": "mc_dsgt", "R": 4, "gamma": 0.4},
        "topology": {"kind": "sun", "beta": 0.9375},
        "run": {"nodes": 16}})
    n, d = 16, 64
    H, y = logreg_dataset(n, 256, d, seed=0)
    _, _, stoch, _, _ = logreg_loss_and_grad(rho=0.1)
    grad_fn = lambda xs, key: stoch(xs, H, y, key, 16)  # noqa: E731
    sched = exp.build_topology(base.topology, n, seed=0)
    algo = alg.mc_dsgt(0.4, R=4)
    rule = engine.make_rule("mc_dsgt", gamma=0.4, R=4)
    names = engine.default_obs(rule)
    wps = algo.weights_per_step
    N, reps = (300, 3) if quick else (1000, 5)
    staged = driver.stage(sched, wps=wps, total=N * wps)

    def _step(obs):
        def core(state, sub, weights, t):
            out = algo.step(state, grad_fn, weights, sub, obs=obs)
            return (out[0], {"obs": out[1]}) if obs else (out, None)
        return driver.bind_step(staged, core)

    steps = {"bare": _step(()), "obs": _step(names)}
    state0 = algo.warm(algo.init(jnp.zeros((n, d))), grad_fn,
                       jax.random.key(1))
    key = [jax.random.key(0)]

    def extra_fn(k):
        key[0], sub = jax.random.split(key[0])
        return sub

    def _loop(step, record=None, tracer=None, steps_n=N):
        t0 = time.time()
        driver.run_loop(step, state0, steps=steps_n, wps=wps,
                        period=staged.period, extra_fn=extra_fn,
                        record=record, tracer=tracer)
        return (time.time() - t0) * 1e6 / steps_n

    w = BenchWriter()
    with tempfile.TemporaryDirectory() as td:

        def run_level(level, steps_n=N):
            if level != "full":
                return _loop(steps["bare" if level == "bare" else "obs"],
                             steps_n=steps_n)
            tracer = Tracer()
            rec = ObsRecorder(
                EventLog(os.path.join(td, f"b{time.time_ns()}.jsonl")),
                every=10, tracer=tracer,
                gap=GapTracker(cell="bench", n=n, beta=0.5))
            us = _loop(steps["obs"], record=rec.record, tracer=tracer,
                       steps_n=steps_n)
            rec.close()
            return us

        levels = ("bare", "injit", "full")
        for lv in levels:  # compile + warm outside the clock
            run_level(lv, steps_n=30)
        res = {lv: [] for lv in levels}
        for _ in range(reps):  # interleave: reps share drift/noise
            for lv in levels:
                res[lv].append(run_level(lv))
    bare, injit, full = (statistics.median(res[lv]) for lv in levels)
    w.row("obs_run_overhead", full,
          f"bare_us={bare:.1f}|injit_us={injit:.1f}"
          f"|injit_overhead={100 * (injit - bare) / bare:.1f}%"
          f"|full_overhead={100 * (full - bare) / bare:.1f}%"
          f"|every=10|ncores={os.cpu_count()}",
          spec=base)

    wspec = exp.from_dict({
        "topology": {"kind": "waypoint-mobility", "radius": 0.45},
        "channel": {"link_drop": 0.2, "burst_loss": 0.1},
        "run": {"nodes": 16}})
    calls = 40 if quick else 120
    window, wps = 32, 2
    horizon = window + wps * (calls + 4) + 8
    ideal = exp.build_topology(wspec.topology, 16, horizon=horizon, seed=0)
    models = exp.build_channel_models(wspec.channel, 0)
    realized = realize_weight_schedule(ideal, models, rounds=horizon)

    class _S:
        x = jnp.ones((16, 8))

    mat_times, rec_times = {}, {}
    for cache in (True, False):
        telem = TelemetryRecorder(realized, wps=wps, window=window,
                                  cache=cache)
        telem._window_rounds(0, window)  # warm numpy/jax paths
        t0 = time.time()
        for k in range(calls):  # the sliding-window materialization alone
            lo = wps * (k + 1)
            telem._window_rounds(lo, lo + window)
        mat_times[cache] = (time.time() - t0) * 1e6 / calls
        telem2 = TelemetryRecorder(realized, wps=wps, window=window,
                                   cache=cache)
        for k in range(4):  # warm outside the clock
            telem2.record(k, window + (k + 1) * wps, _S(), None, 0.0)
        t0 = time.time()
        for k in range(4, 4 + calls):
            telem2.record(k, window + (k + 1) * wps, _S(), None, 0.0)
        rec_times[cache] = (time.time() - t0) * 1e6 / calls
    w.row("obs_telemetry_cache", mat_times[True],
          f"uncached_us={mat_times[False]:.1f}"
          f"|speedup={mat_times[False] / max(mat_times[True], 1e-9):.2f}x"
          f"|record_us={rec_times[True]:.0f}|window={window}", spec=wspec)
    w.dump("experiments/bench/BENCH_obs.json")


# ---------------------------------------------------------------------------
# Personalized fleet serving (continuous batching)
# ---------------------------------------------------------------------------

def bench_serve(quick: bool) -> None:
    """Continuous-batching serve throughput (ISSUE 10): one row per
    decode-slot count, serving synthetic user-affinity traffic against a
    stacked reduced-qwen fleet through :func:`repro.serve.serve_fleet`.
    derived = prefill/decode token throughput and the p50/p95 request
    latency (larger slot tables amortize the vmapped decode but queue
    admissions, so latency and throughput trade off against ``batch``).
    Row throughput = completed requests/s — the regression-gate metric.
    Writes experiments/bench/BENCH_serve.json."""
    from repro import exp
    from repro.serve import serve_fleet

    fleet_n = 4
    requests = 16 if quick else 64
    base = exp.ExperimentSpec(
        model=exp.ModelRef(kind="arch", arch="qwen1.5-0.5b",
                           preset="reduced"),
        run=exp.RunSpec(nodes=fleet_n),
        serve=exp.ServeSpec(requests=requests, prompt_len=16, max_new=8,
                            dtype="f32"))
    b = exp.build(base)
    keys = jax.random.split(jax.random.key(0), fleet_n)
    fleet = jax.vmap(lambda k: b.model.init(k, jnp.float32))(keys)
    w = BenchWriter()
    for batch in ((2, 8) if quick else (2, 8, 16)):
        spec = exp.with_field(base, "serve.batch", batch)
        serve_fleet(b.model, fleet, spec.serve)  # warmup/compile pass
        t0 = time.time()
        res = serve_fleet(b.model, fleet, spec.serve)
        us = (time.time() - t0) * 1e6 / requests
        tp = res.throughput
        w.row(f"serve_batch{batch}", us,
              f"prefill_tok_s={tp['prefill_tok_s']}"
              f"|decode_tok_s={tp['decode_tok_s']}"
              f"|p50_ms={tp['latency_p50_ms']}"
              f"|p95_ms={tp['latency_p95_ms']}"
              f"|requests={tp['requests']}|fleet={fleet_n}",
              spec=spec, throughput=tp["requests_per_s"])
    w.dump("experiments/bench/BENCH_serve.json")


# ---------------------------------------------------------------------------
# Roofline summary (from dry-run artifacts)
# ---------------------------------------------------------------------------

def bench_roofline(quick: bool) -> None:
    paths = sorted(glob.glob("experiments/dryrun/*.json"))
    if not paths:
        record("roofline_summary", 0.0, "no-dryrun-artifacts")
        return
    from repro.launch.roofline import analyse
    t0 = time.time()
    dom = {"compute": 0, "memory": 0, "collective": 0}
    for p in paths:
        rec = json.load(open(p))
        dom[analyse(rec)["dominant"]] += 1
    us = (time.time() - t0) * 1e6
    record("roofline_summary", us / len(paths),
           f"compute:{dom['compute']}|memory:{dom['memory']}"
           f"|collective:{dom['collective']}")


BENCHES = [
    ("theorem3", bench_theorem3),
    ("compression", bench_compression),
    ("gossip_plan", bench_gossip_plan),
    ("sim", bench_sim),
    ("sparse", bench_sparse),
    ("engine_step", bench_engine_step),
    ("async", bench_async),
    ("obs", bench_obs),
    ("serve", bench_serve),
    ("kernels", bench_kernels),
    ("theorem4", bench_theorem4),
    ("table1_rate_T", bench_table1_rate_T),
    ("table1_speedup_n", bench_table1_speedup_n),
    ("r_ablation", bench_r_ablation),
    ("figure2", bench_figure2),
    ("roofline", bench_roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benchmarks whose name contains SUBSTR "
                         "(e.g. --only engine_step for the CI artifact)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results to a BENCH json (default "
                         "experiments/bench/BENCH.json under --quick)")
    args, _ = ap.parse_known_args()
    quick = args.quick
    json_path = args.json or (quick and "experiments/bench/BENCH.json" or None)
    if args.json:  # --json opts into the root-canonical BENCH mirror
        global MIRROR_TO_ROOT
        MIRROR_TO_ROOT = True

    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only is None or args.only in name:
            fn(quick)
    if json_path:
        if os.path.dirname(json_path):
            os.makedirs(os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(ALL_ROWS, f, indent=1)
        print(f"wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
