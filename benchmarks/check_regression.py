"""Throughput-regression gate over the BENCH json artifacts.

Compares the freshly measured BENCH files in ``--current`` (what
``python -m benchmarks.run --quick --only ...`` just wrote, default
``experiments/bench``) against the checked-in baselines in
``--baseline`` (default ``benchmarks/baselines``).  Rows are matched by
``name`` within the same BENCH_*.json file; a matched row FAILS when its
throughput dropped by more than ``--threshold`` (default 25%) relative
to the baseline.

The gate is deliberately one-sided and loose: the baselines were taken
on a small shared CPU container, so run-to-run noise of +-15% is normal
and only a large sustained drop is treated as a real regression.  Rows
present on only one side are reported but never fail the gate (new
benches land before their baseline; retired benches linger in the
baseline until it is regenerated).

    python -m benchmarks.check_regression
    python -m benchmarks.check_regression --threshold 0.4 --only obs

Exit status: 0 = no regression, 1 = at least one row regressed,
2 = nothing to compare (missing dirs or no overlapping files).

Regenerating baselines (after an intentional perf change)::

    PYTHONPATH=src python -m benchmarks.run --quick --only <bench>
    cp experiments/bench/BENCH_<bench>.json benchmarks/baselines/
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_rows(path: str) -> dict[str, dict]:
    """name -> row for one BENCH json (a list of row dicts)."""
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows if "name" in r}


def compare_file(base_path: str, cur_path: str, threshold: float):
    """Yield (name, baseline_tp, current_tp, ratio, status) per row.

    status: 'ok' | 'regressed' | 'baseline-only' | 'current-only'
    ratio is current/baseline throughput (1.0 = unchanged), None when a
    side is missing or reports no throughput.
    """
    base, cur = load_rows(base_path), load_rows(cur_path)
    for name in sorted(set(base) | set(cur)):
        if name not in cur:
            yield name, base[name].get("throughput"), None, None, \
                "baseline-only"
            continue
        if name not in base:
            yield name, None, cur[name].get("throughput"), None, \
                "current-only"
            continue
        b = base[name].get("throughput")
        c = cur[name].get("throughput")
        if not b or c is None:
            yield name, b, c, None, "ok"  # no throughput to judge
            continue
        ratio = c / b
        status = "regressed" if ratio < 1.0 - threshold else "ok"
        yield name, b, c, ratio, status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="dir of checked-in BENCH_*.json baselines")
    ap.add_argument("--current", default="experiments/bench",
                    help="dir of freshly measured BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional throughput drop "
                         "(0.25 = fail below 75%% of baseline)")
    ap.add_argument("--only", default=None,
                    help="restrict to BENCH files whose name contains "
                         "this substring (e.g. 'obs', 'engine')")
    args = ap.parse_args(argv)

    pattern = os.path.join(args.baseline, "BENCH_*.json")
    base_paths = sorted(glob.glob(pattern))
    if args.only:
        base_paths = [p for p in base_paths if args.only in
                      os.path.basename(p)]
    if not base_paths:
        print(f"check_regression: no baselines match {pattern}",
              file=sys.stderr)
        return 2

    compared = 0
    regressed: list[str] = []
    for base_path in base_paths:
        fname = os.path.basename(base_path)
        cur_path = os.path.join(args.current, fname)
        if not os.path.exists(cur_path):
            print(f"-- {fname}: not measured in {args.current}, skipped")
            continue
        print(f"-- {fname}")
        for name, b, c, ratio, status in compare_file(
                base_path, cur_path, args.threshold):
            if status in ("ok", "regressed"):
                compared += 1
            mark = {"ok": "ok ", "regressed": "REG", "baseline-only": "?- ",
                    "current-only": "-? "}[status]
            rtxt = f"{ratio:5.2f}x" if ratio is not None else "   -  "
            btxt = f"{b:12.1f}" if b is not None else "           -"
            ctxt = f"{c:12.1f}" if c is not None else "           -"
            print(f"   {mark} {name:32s} base={btxt} cur={ctxt} {rtxt}")
            if status == "regressed":
                regressed.append(f"{fname}:{name}")
    if compared == 0:
        print("check_regression: no overlapping rows to compare",
              file=sys.stderr)
        return 2
    if regressed:
        print(f"\ncheck_regression: {len(regressed)} row(s) dropped more "
              f"than {args.threshold:.0%} below baseline throughput:")
        for r in regressed:
            print(f"  {r}")
        return 1
    print(f"\ncheck_regression: {compared} row(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
